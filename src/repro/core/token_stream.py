"""The token stream I_e — chunked, blocked-matmul replacement for Faiss+PQ.

Paper §IV: I_e yields (q, t, sim(q, t)) tuples for every vocabulary token t
with sim >= alpha to some query element, in globally descending similarity
order, realised with a Faiss index plus a |Q|-slot priority queue.

TPU adaptation (DESIGN.md §2): the index probe is a blocked similarity
matmul (MXU) over vocabulary tiles — `repro.kernels.cosine_topk` is the
fused Pallas kernel for the serving path; here the same block computation
runs through the jnp provider and the >=alpha entries are compacted host
side (compaction is inherently dynamic-shape, i.e. host work in either
implementation — the paper also walks its priority queue on the host).

The refinement phase consumes the stream *expanded to posting-level events*
through the inverted index (paper: "probing I_s"), still in descending
order:  (set, q, slot, sim) per posting of each streamed token.

Multi-query serving: :func:`build_token_stream_batch` stacks B queries into
one (sum |Q_b| x |V|) blocked sweep — one provider dispatch and one host
compaction per vocab block for the whole batch — and returns per-query
streams bit-identical to B single-query calls.

A stream depends only on (query, provider, alpha) — NOT on the partition —
so the partition scheduler (``repro.core.scheduler``) builds each query's
stream once and expands it through every partition's inverted index,
replacing the historical per-partition rebuild with P calls to
:func:`expand_to_events` per query.

Cross-REQUEST reuse (DESIGN.md §3.2): because the stream is a pure
function of that (query tokens, alpha, provider) key, repeated or
overlapping requests can skip the blocked sweep entirely —
:class:`TokenStreamCache` is the LRU the request engine (and
``KoiosSearch(stream_cache=...)``) consults, and
:func:`build_token_stream_batch_cached` the cache-aware build that
sweeps only the misses (still as ONE stacked matmul) and returns
streams bit-identical to the uncached batch build.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from .inverted_index import InvertedIndex
from .types import SetCollection, pad_ids_pow2, pow2

# The provider sweep (and the cosine_topk kernel) compiles one program
# per stacked-row count; serving coalesces arbitrary request mixes, so
# without the ``pad_ids_pow2`` row bucket every new cohort composition
# would be a fresh compile (pad rows are sliced off — bit-identical).


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """All pairs (q position, token, sim >= alpha), descending by sim."""

    q_pos: np.ndarray    # (T,) int32 — position of the query element in Q
    token: np.ndarray    # (T,) int32 — vocabulary token id
    sim: np.ndarray      # (T,) float32, non-increasing

    def __len__(self) -> int:
        return len(self.sim)


@dataclasses.dataclass(frozen=True)
class EventStream:
    """Posting-level expansion of a TokenStream (still descending by sim)."""

    set_id: np.ndarray   # (E,) int32
    q_pos: np.ndarray    # (E,) int32
    slot: np.ndarray     # (E,) int32 — flat token-array slot (t-side
    #                      identity; int64 only when the repository
    #                      overflows int32 slots — see types.slot_dtype)
    sim: np.ndarray      # (E,) float32, non-increasing
    n_tuples: int        # stream tuples that produced these events

    def __len__(self) -> int:
        return len(self.sim)


def _finalize_stream(query: np.ndarray, q_pos: np.ndarray, token: np.ndarray,
                     sim: np.ndarray, vocab: int) -> TokenStream:
    """Identity-pair completion + global descending sort for one query."""
    nq = len(query)
    # Identity pairs (q, q, 1.0) — add any that the provider missed (e.g.
    # degenerate embeddings) and dedupe.
    in_vocab = query < vocab
    id_q = np.arange(nq, dtype=np.int32)[in_vocab]
    id_t = query[in_vocab]
    key = q_pos.astype(np.int64) * vocab + token
    id_key = id_q.astype(np.int64) * vocab + id_t
    missing = ~np.isin(id_key, key)
    q_pos = np.concatenate([q_pos, id_q[missing]])
    token = np.concatenate([token, id_t[missing]])
    sim = np.concatenate([sim, np.ones(missing.sum(), np.float32)])

    # identity pairs must carry sim exactly 1.0 even if the provider returned
    # a slightly different value
    ident = query[q_pos] == token
    sim = np.where(ident, np.float32(1.0), sim)

    order = np.argsort(-sim, kind="stable")
    return TokenStream(q_pos=q_pos[order], token=token[order], sim=sim[order])


def _build_stream_entries_kernel(stacked: np.ndarray, sim_provider,
                                 alpha: float, block_size: int):
    """(row, token, sim >= alpha) triples via the ``cosine_topk`` Pallas
    kernel (DESIGN.md §7) instead of the jnp provider sweep.

    The kernel keeps a running top-k on-chip, so the (rows x |V|) score
    matrix never round-trips to HBM; ``k`` doubles until no row's k-th
    score clears alpha (then the top-k provably contains every >= alpha
    entry).  Per-entry math matches the provider path bit for bit: the
    kernel dots the same L2-normalized rows the provider normalizes per
    block (row-wise normalization is subset-invariant), and clip +
    identity-fix are applied to the returned values exactly as
    ``EmbeddingSimilarity`` applies them to score blocks.  Entries are
    re-ordered to the provider sweep's (vocab block, row, token) order so
    downstream admission order — and therefore every bound — is
    identical.
    """
    import jax.numpy as jnp

    from ..kernels import ops as kops
    from ..runtime import instrument

    vocab = sim_provider.vocab_size
    if not len(stacked):
        z = np.zeros(0, np.int64)
        return z, z.astype(np.int32), np.zeros(0, np.float32)
    # cached device-resident normalized table; query rows gathered on
    # device (no full-table round-trip per call).  Rows pad to a pow2
    # bucket so steady-state serving reuses compiled programs (pad rows
    # are sliced off before any value is consumed — bit-identical).
    from .similarity import normalized_table_for
    table_n = normalized_table_for(sim_provider)
    qe = table_n[jnp.asarray(pad_ids_pow2(stacked))]
    k = min(128, vocab)
    while True:
        instrument.record("h2d:stream_kernel_dispatch")
        instrument.record("d2h:stream_materialize")
        vals, idx = kops.cosine_topk(qe, table_n, k=k)
        vals = np.asarray(vals)[:len(stacked)]
        idx = np.asarray(idx)[:len(stacked)]
        if k == vocab or float(vals[:, -1].max()) < alpha:
            break
        k = min(k * 2, vocab)          # a row saturated: deepen the top-k

    # provider-path value semantics: clip to [0, 1], identity pairs 1.0
    vals = np.clip(vals, 0.0, 1.0)
    vals = np.where(idx == stacked[:, None], np.float32(1.0),
                    vals).astype(np.float32)
    rows, cols = np.nonzero(vals >= alpha)
    q_rows = rows.astype(np.int64)
    token = idx[rows, cols].astype(np.int32)
    sim = vals[rows, cols]

    # identity pairs the top-k cutoff may have missed (always >= alpha)
    key = q_rows * vocab + token
    id_key = np.arange(len(stacked), dtype=np.int64) * vocab + stacked
    missing = ~np.isin(id_key, key)
    q_rows = np.concatenate([q_rows, np.nonzero(missing)[0]])
    token = np.concatenate([token, stacked[missing]])
    sim = np.concatenate([sim, np.ones(missing.sum(), np.float32)])

    # the provider sweep emits (block asc, stacked row asc, token asc)
    order = np.lexsort((token, q_rows, token // block_size))
    return q_rows[order], token[order], sim[order]


def build_token_stream_batch(queries, sim_provider, alpha: float,
                             block_size: int = 4096,
                             use_kernel: bool = False) -> "list[TokenStream]":
    """Token streams for B queries from ONE blocked similarity sweep.

    The queries are stacked into a single (sum |Q_b|, |V|-block) similarity
    matmul per vocabulary block — B times fewer provider dispatches and one
    host-side ``>= alpha`` compaction per block instead of B of them.  Rows
    of the stacked result are exactly the rows each per-query call would
    compute, and the per-query finalize (identity pairs, stable sort) is
    shared with :func:`build_token_stream`, so the returned streams are
    bit-identical to the per-query path.

    ``sim_provider`` must expose ``query_vs_vocab_block(q_ids, lo, hi)`` and
    ``vocab_size``.  Identity pairs (q, q) are always included with sim 1.0
    (paper §V: a query element is returned for itself on first probe — this
    initialises bounds with the vanilla overlap and covers out-of-vocabulary
    elements).
    """
    queries = [np.asarray(q, dtype=np.int32) for q in queries]
    if not queries:
        return []
    vocab = sim_provider.vocab_size
    stacked = np.concatenate(queries)
    # row ranges of each query inside the stacked matrix
    bounds = np.zeros(len(queries) + 1, np.int64)
    np.cumsum([len(q) for q in queries], out=bounds[1:])

    # the kernel path computes cosine from the provider's embedding table;
    # any other similarity (e.g. n-gram Jaccard) falls back to the
    # provider sweep — same gate as the fused schedule's
    if use_kernel and getattr(sim_provider, "name", None) == "cosine":
        q_rows, token, sim = _build_stream_entries_kernel(
            stacked, sim_provider, alpha, block_size)
        out = []
        for b, query in enumerate(queries):
            m = (q_rows >= bounds[b]) & (q_rows < bounds[b + 1])
            out.append(_finalize_stream(
                query, (q_rows[m] - bounds[b]).astype(np.int32),
                token[m], sim[m], vocab))
        return out

    qs = [[] for _ in queries]
    ts = [[] for _ in queries]
    ss = [[] for _ in queries]
    # pow2 row bucket: one compiled sweep program per (bucket, block)
    # instead of one per cohort composition (pad rows sliced off)
    stacked_in = pad_ids_pow2(stacked)
    for lo in range(0, vocab, block_size):
        hi = min(lo + block_size, vocab)
        block = np.asarray(sim_provider.query_vs_vocab_block(
            stacked_in, lo, hi))[:len(stacked)]
        qi, tj = np.nonzero(block >= alpha)          # one compaction, B queries
        if not len(qi):
            continue
        vals = block[qi, tj].astype(np.float32)
        # qi is ascending (row-major nonzero), so each query's rows are one
        # contiguous slice; split at the stacked row bounds
        cuts = np.searchsorted(qi, bounds)
        for b in range(len(queries)):
            s, e = cuts[b], cuts[b + 1]
            if e > s:
                qs[b].append((qi[s:e] - bounds[b]).astype(np.int32))
                ts[b].append((tj[s:e] + lo).astype(np.int32))
                ss[b].append(vals[s:e])

    out = []
    for b, query in enumerate(queries):
        if qs[b]:
            q_pos = np.concatenate(qs[b])
            token = np.concatenate(ts[b])
            sim = np.concatenate(ss[b])
        else:
            q_pos = np.zeros(0, np.int32)
            token = np.zeros(0, np.int32)
            sim = np.zeros(0, np.float32)
        out.append(_finalize_stream(query, q_pos, token, sim, vocab))
    return out


class TokenStreamCache:
    """Byte-bounded LRU cache of token streams keyed by (query tokens,
    alpha, provider, collection epoch).

    Streams are pure functions of the key (module docstring), and
    :class:`TokenStream` is frozen with arrays no consumer mutates, so a
    hit returns the cached object itself — zero copies, bit-identical to
    a rebuild.  The provider component of the key is its ``id`` (the
    provider is pinned by the cache so the id cannot be recycled): two
    providers with equal tables are distinct keys (correct, merely
    conservative), while a provider whose table is mutated in place
    would serve stale streams — providers are immutable by convention
    everywhere else in the repo.

    The bound is BYTES, not entries (``max_bytes``): streams vary ~100x
    in footprint with query size x alpha (a permissive alpha on a large
    query yields a long (q_pos, token, sim) tuple list), so an entry
    count bounds nothing — a byte budget is what actually caps host
    memory.  Entries larger than the whole budget are not cached at all
    (they would only evict everything else and then miss next time).

    The key carries the serving layer's collection EPOCH (DESIGN.md
    §6.5).  Streams do not read the collection — but the entries
    belong to an engine whose refinement/verification state is epoch-
    pinned, and keying by epoch makes "a commit cannot serve stale
    state" a cache invariant rather than a per-caller audit: after
    ``set_epoch`` bumps, every old-epoch entry is unreachable (and
    drains off the LRU cold end under the byte budget).

    ``hits``/``misses``/``evictions`` are cumulative; the request
    engine surfaces them per serving window via
    ``runtime.instrument.EngineCounters``.
    """

    def __init__(self, max_bytes: int = 64 << 20):
        assert max_bytes >= 1
        self.max_bytes = int(max_bytes)
        self.bytes = 0                   # current cached payload bytes
        self.epoch = 0                   # collection epoch key component
        self._entries: "OrderedDict[tuple, TokenStream]" = OrderedDict()
        # pin each keyed provider so its id cannot be recycled by the
        # allocator while entries keyed on it may still be alive (a
        # handful of providers per process; never evicted)
        self._providers: dict = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def set_epoch(self, epoch: int) -> None:
        """Bump the epoch key component (engine resync): entries of
        older epochs become unreachable immediately and age off the LRU
        cold end under the byte budget."""
        self.epoch = int(epoch)

    @staticmethod
    def _nbytes(stream: TokenStream) -> int:
        return (stream.q_pos.nbytes + stream.token.nbytes
                + stream.sim.nbytes)

    def key(self, query: np.ndarray, alpha: float, sim_provider) -> tuple:
        q = np.ascontiguousarray(np.asarray(query, np.int32))
        self._providers[id(sim_provider)] = sim_provider
        return (q.tobytes(), float(alpha), id(sim_provider), self.epoch)

    def __len__(self) -> int:
        return len(self._entries)

    def contains(self, key: tuple) -> bool:
        """Membership probe that touches neither LRU order nor counters
        (per-request hit attribution in the engine)."""
        return key in self._entries

    def get(self, key: tuple):
        """Cached stream for ``key`` (bumping LRU + hit/miss counters)."""
        stream = self._entries.get(key)
        if stream is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return stream

    def put(self, key: tuple, stream: TokenStream) -> None:
        n = self._nbytes(stream)
        if n > self.max_bytes:
            return                        # would evict the whole cache
        prev = self._entries.pop(key, None)
        if prev is not None:
            self.bytes -= self._nbytes(prev)
        self._entries[key] = stream
        self.bytes += n
        while self.bytes > self.max_bytes and self._entries:
            _, old = self._entries.popitem(last=False)
            self.bytes -= self._nbytes(old)
            self.evictions += 1

    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {"size": len(self._entries), "bytes": self.bytes,
                "max_bytes": self.max_bytes, "epoch": self.epoch,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / lookups if lookups else 0.0}

    def describe(self) -> dict:
        """Size-accounting summary (alias of :meth:`stats` — the serving
        observability surface)."""
        return self.stats()


def build_token_stream_batch_cached(queries, sim_provider, alpha: float,
                                    cache: TokenStreamCache,
                                    block_size: int = 4096,
                                    use_kernel: bool = False
                                    ) -> "list[TokenStream]":
    """Cache-aware :func:`build_token_stream_batch`: hits skip the sweep,
    misses build in ONE stacked sweep and populate the cache.

    Duplicate queries within one call build once (the later occurrences
    count as hits — they are served without a sweep).  Each per-query
    stream is bit-identical to the uncached batch build: rows of the
    stacked sweep are exactly the rows a per-query call computes, so
    sweeping only the misses changes nothing (see the batch builder's
    contract).
    """
    queries = [np.asarray(q, dtype=np.int32) for q in queries]
    keys = [cache.key(q, alpha, sim_provider) for q in queries]
    out: "list[Optional[TokenStream]]" = [None] * len(queries)
    build_idx: "list[int]" = []          # first occurrence of each missed key
    followers: "dict[tuple, list[int]]" = {}
    for i, key in enumerate(keys):
        if key in followers:             # duplicate miss within this call
            followers[key].append(i)
            cache.hits += 1
            continue
        stream = cache.get(key)
        if stream is None:
            build_idx.append(i)
            followers[key] = []
        else:
            out[i] = stream
    if build_idx:
        built = build_token_stream_batch(
            [queries[i] for i in build_idx], sim_provider, alpha,
            block_size=block_size, use_kernel=use_kernel)
        for i, stream in zip(build_idx, built):
            cache.put(keys[i], stream)
            out[i] = stream
            for j in followers[keys[i]]:
                out[j] = stream
    return out


def build_token_stream(query: np.ndarray, sim_provider, alpha: float,
                       block_size: int = 4096) -> TokenStream:
    """Single-query token stream (see :func:`build_token_stream_batch`)."""
    return build_token_stream_batch([query], sim_provider, alpha,
                                    block_size)[0]


def expand_to_events(stream: TokenStream, index: InvertedIndex) -> EventStream:
    """Expand stream tuples through the inverted index to per-set events.

    Fully vectorized: posting ranges become one flat gather index built from
    repeated range starts plus within-range offsets (cumulative-offset
    trick) — no Python loop over stream tokens.
    """
    counts = index.posting_counts()
    reps = counts[stream.token]
    total = int(reps.sum())
    q_pos = np.repeat(stream.q_pos, reps)
    sim = np.repeat(stream.sim, reps)
    if total:
        starts = index.tok_indptr[stream.token]      # (T,) posting-range lo
        ends = np.cumsum(reps)                       # event offset per tuple
        within = np.arange(total, dtype=np.int64) - np.repeat(ends - reps,
                                                              reps)
        gather = np.repeat(starts, reps) + within
        set_id = index.posting_set[gather]
        slot = index.posting_slot[gather]
    else:
        set_id = np.zeros(0, dtype=np.int32)
        slot = np.zeros(0, dtype=index.posting_slot.dtype)
    return EventStream(set_id=set_id, q_pos=q_pos, slot=slot, sim=sim,
                       n_tuples=len(stream))


def pad_events(events: EventStream, chunk: int):
    """Pad event arrays to a power-of-two number of ``chunk``-sized chunks
    (set_id = -1 padding).  Pow2 chunk counts bound jit recompilations of the
    refinement scan to O(log stream-length) distinct shapes."""
    e = len(events)
    n_chunks = pow2(max(1, -(-e // chunk)))
    total = n_chunks * chunk
    pad = total - e

    def _pad(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])

    # pad sims repeat the final (lowest) real sim — a valid stream
    # position for the filter pass.  An EMPTY stream has no valid
    # position: pad with 0.0 (not 1.0 — a 1.0 s_now would inflate the
    # pad chunk's unseen-remainder term if any set were ever marked
    # seen; with 0.0 the pad chunk is inert by construction).
    last_sim = events.sim[-1] if e else np.float32(0.0)
    return (
        _pad(events.set_id, -1).reshape(n_chunks, chunk),
        _pad(events.q_pos, 0).reshape(n_chunks, chunk),
        _pad(events.slot, 0).reshape(n_chunks, chunk),
        _pad(events.sim, last_sim).reshape(n_chunks, chunk),
    )


def event_ranks(ev_set: np.ndarray) -> np.ndarray:
    """Within-(chunk, set) occurrence index of every event — the
    *set-segmented* layout metadata of the refinement scan (DESIGN.md
    §2): events with rank t form level t of the segmented admission
    schedule, and within a level all events touch distinct sets.

    ``ev_set`` is the (n_chunks, chunk) padded set-id array from
    :func:`pad_events`; returns an int32 array of the same shape.
    Padding events (set -1) receive ranks too (they group as one
    segment) but are masked out of both the admission and the
    level-count computation by their sentinel set id.
    """
    n, c = ev_set.shape
    m = n * c
    if m == 0:
        return np.zeros((n, c), np.int32)
    flat_set = ev_set.reshape(-1).astype(np.int64)
    iota = np.arange(m, dtype=np.int64)
    chunk_of = iota // c
    order = np.lexsort((iota, flat_set, chunk_of))   # stable within segment
    key_chunk = chunk_of[order]
    key_set = flat_set[order]
    start = np.ones(m, bool)
    start[1:] = (key_chunk[1:] != key_chunk[:-1]) \
        | (key_set[1:] != key_set[:-1])
    seg_start = np.maximum.accumulate(np.where(start, iota, 0))
    rank = np.empty(m, np.int32)
    rank[order] = (iota - seg_start).astype(np.int32)
    return rank.reshape(n, c)


def pack_events_segmented(ev_set: np.ndarray, ev_q: np.ndarray,
                          ev_slot: np.ndarray, ev_sim: np.ndarray):
    """Lane-pack padded event chunks into the set-segmented (W, L)
    layout the segmented refinement scan consumes (DESIGN.md §2).

    Row ``t`` of a chunk holds its level-``t`` events — the rank-``t``
    event of every set that has one — compacted left into ``L`` fixed-
    width pow2 lanes (set id -1 pads).  Within a row all events touch
    pairwise-distinct sets, so the scan admits a whole row as one
    vectorized scatter; down the rows each set's events appear in
    stream order, preserving the only load-bearing order.  ``W`` (pow2)
    covers the deepest per-set segment and ``L`` (pow2) the widest
    level across all chunks, so the packed arrays are at most a small
    constant larger than the flat chunks while the sequential depth
    drops from ``chunk`` to ``W``.

    Returns (set (n, W, L), q, slot, sim, s_now (n,)) — ``s_now`` is
    each chunk's final stream-order sim (the filter-pass position that
    the packed layout no longer encodes positionally).
    """
    n, c = ev_set.shape
    ranks = event_ranks(ev_set)
    flat_valid = (ev_set >= 0).reshape(-1)
    flat_rank = ranks.reshape(-1).astype(np.int64)
    m = n * c
    iota = np.arange(m, dtype=np.int64)
    chunk_of = iota // c
    vidx = iota[flat_valid]
    order = np.lexsort((vidx, flat_rank[flat_valid], chunk_of[flat_valid]))
    vs = vidx[order]
    nv = len(vs)
    key_c, key_r = chunk_of[vs], flat_rank[vs]
    start = np.ones(nv, bool)
    if nv:
        start[1:] = (key_c[1:] != key_c[:-1]) | (key_r[1:] != key_r[:-1])
    lane = np.arange(nv) - np.maximum.accumulate(
        np.where(start, np.arange(nv), 0)) if nv else np.zeros(0, np.int64)
    W = pow2(int(key_r.max()) + 1 if nv else 1)
    L = pow2(int(lane.max()) + 1 if nv else 1)

    set3 = np.full((n, W, L), -1, np.int32)
    q3 = np.zeros((n, W, L), np.int32)
    slot3 = np.zeros((n, W, L), ev_slot.dtype)
    sim3 = np.zeros((n, W, L), np.float32)
    set3[key_c, key_r, lane] = ev_set.reshape(-1)[vs]
    q3[key_c, key_r, lane] = ev_q.reshape(-1)[vs]
    slot3[key_c, key_r, lane] = ev_slot.reshape(-1)[vs]
    sim3[key_c, key_r, lane] = ev_sim.reshape(-1)[vs]
    return set3, q3, slot3, sim3, ev_sim[:, -1].astype(np.float32)
