"""Shared benchmark fixtures: Table-I-matched corpora, embeddings, queries,
timing, and data-structure memory accounting (the paper's footprint
metric)."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import (EmbeddingSimilarity, KoiosIndex, KoiosSearch,
                        SearchParams)
from repro.data import dataset_preset, make_embeddings, sample_queries

# CPU-feasible scales of the paper's four datasets (full stats in
# repro.data.PRESETS; EXPERIMENTS.md reports the scale factors).
BENCH_SCALES = {"dblp": 0.05, "opendata": 0.02, "twitter": 0.015,
                "wdc": 0.002}
EMB_DIM = 32


@functools.lru_cache(maxsize=None)
def world(dataset: str, scale: float | None = None, dim: int = EMB_DIM,
          seed: int = 0):
    scale = BENCH_SCALES[dataset] if scale is None else scale
    coll = dataset_preset(dataset, scale=scale, seed=seed)
    emb = make_embeddings(coll.vocab_size, dim=dim, seed=seed)
    sim = EmbeddingSimilarity(emb)
    return coll, sim


@functools.lru_cache(maxsize=None)
def index_for(dataset: str):
    coll, sim = world(dataset)
    return KoiosIndex.build(coll)


def queries_for(dataset: str, n: int = 3, seed: int = 1):
    coll, _ = world(dataset)
    return sample_queries(coll, n, seed=seed)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0


def memory_footprint_bytes(dataset: str, nq: int) -> dict:
    """Deterministic data-structure footprint (paper §VIII-D): inverted
    index + per-set filter state + bitmasks for a |Q|=nq query."""
    coll, _ = world(dataset)
    inv = index_for(dataset).inv
    n = coll.num_sets
    q_words = max(1, -(-nq // 32))
    state = n * (4 + 4 + 4 + 4 + 1 + 1)        # S,l,T,d,seen,alive
    masks = 2 * n * q_words * 4                # qmatched/qseen
    slots = coll.total_tokens                  # slot_matched
    return {
        "inverted_index": inv.memory_bytes(),
        "filter_state": state + masks + slots,
        "total": inv.memory_bytes() + state + masks + slots,
    }


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
