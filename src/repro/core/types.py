"""Core datatypes for KOIOS semantic overlap search.

A :class:`SetCollection` is the repository L of the paper: a collection of
sets of tokens drawn from a shared vocabulary D.  Sets are stored in CSR
layout (``set_indptr`` / ``set_tokens``) so the whole repository is three
flat arrays — the layout every phase of the search consumes directly and
the layout that shards cleanly across a device mesh (contiguous range of
sets per shard).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def pow2(n: int, lo: int = 1) -> int:
    """Smallest power of two >= ``n`` (floored at ``lo``) — THE
    shape-bucket rounding every padded dimension shares (chunk counts,
    solver batches, wave configs, sweep rows, pairwise dispatch:
    DESIGN.md §2/§3.2).  One implementation so the bucket invariant
    tests/test_recompile.py asserts cannot diverge between stages."""
    p = lo
    while p < n:
        p *= 2
    return p


def slot_dtype(total_slots: int):
    """Narrowest integer dtype for flat token-array slots (and posting
    ids): int32 whenever the repository fits — always at bench scales —
    halving event-transfer bytes and scatter width.  int64 repositories
    (>= 2**31 flat slots) keep the wide dtype; callers that *require*
    the narrow form (device uploads) assert via :func:`assert_int32`."""
    return np.int32 if total_slots < 2 ** 31 else np.int64


def assert_int32(n: int, what: str) -> int:
    """Guard a count that is about to be narrowed to int32 on device.
    A real exception (not ``assert``): silent wraparound here would mean
    wrong search results, and ``python -O`` must not strip the guard."""
    if n >= 2 ** 31:
        raise ValueError(
            f"{what} = {n} overflows int32 — device-resident expansion "
            f"and the int32 posting/slot layout cap at 2**31-1 entries")
    return n


def pad_ids_pow2(ids: np.ndarray, lo: int = 8) -> np.ndarray:
    """Pad an id vector to a pow2 length with id 0.  Callers slice the
    padded rows/cols off before any value is consumed, and provider ops
    are row/col-independent, so the retained values are bit-identical."""
    pad = pow2(max(len(ids), 1), lo) - len(ids)
    if pad == 0:
        return ids
    return np.concatenate([ids, np.zeros(pad, ids.dtype)])


class QueryValidationError(ValueError):
    """A query failed admission-time validation (empty, malformed, or
    backed by non-finite embedding rows) — raised/reported BEFORE any
    search work, never silently producing a garbage top-k."""


def validate_query(query, sim_provider=None) -> np.ndarray:
    """Validate one query token set at admission time; returns it as a
    contiguous int32 array.

    Structural checks: 1-D, non-empty, integer dtype, no negative ids.
    Out-of-vocabulary ids (>= vocab) are LEGAL — the identity-pair rule
    clamps an OOV token's self-similarity to 1.0, so unseen tokens are a
    supported query feature, not an error.  When ``sim_provider`` exposes
    an embedding ``table``, the IN-vocab rows the query touches are
    checked finite: a NaN/Inf embedding row would poison every similarity
    the token participates in (and through theta_lb, potentially the
    whole batch's pruning), so it is rejected here with a typed error
    instead of surfacing as a wrong result."""
    q = np.asarray(query)
    if q.ndim != 1:
        raise QueryValidationError(
            f"query must be a 1-D token array, got shape {q.shape}")
    if q.size == 0:
        raise QueryValidationError("query set is empty")
    if not np.issubdtype(q.dtype, np.integer):
        raise QueryValidationError(
            f"query tokens must be integers, got dtype {q.dtype}")
    if int(q.min()) < 0:
        raise QueryValidationError(
            f"query contains negative token id {int(q.min())}")
    table = getattr(sim_provider, "table", None)
    if table is not None:
        # per-row finiteness, computed ONCE per provider on the host and
        # cached there: a per-query device gather would compile a fresh
        # XLA executable for every distinct query length (an unbounded
        # compile stream on the admission path — each submit is O(|q|)
        # host indexing instead)
        finite = getattr(sim_provider, "_finite_rows", None)
        if finite is None:
            finite = np.isfinite(np.asarray(table)).all(axis=1)
            try:
                sim_provider._finite_rows = finite
            except AttributeError:
                pass                       # unwritable provider: recompute
        vocab = int(table.shape[0])
        in_vocab = np.unique(q[q < vocab]).astype(np.int64)
        if len(in_vocab) and not finite[in_vocab].all():
            bad = in_vocab[~finite[in_vocab]]
            raise QueryValidationError(
                f"non-finite embedding row(s) for query token(s) "
                f"{bad[:4].tolist()}")
    return np.ascontiguousarray(q, np.int32)


@dataclasses.dataclass(frozen=True)
class SetCollection:
    """Repository of sets in CSR layout.

    set i occupies ``set_tokens[set_indptr[i]:set_indptr[i+1]]``; tokens are
    vocabulary ids in ``[0, vocab_size)``.  Tokens within a set are distinct
    (sets, not bags) — enforced by the constructors in ``repro.data.sets``.
    """

    set_indptr: np.ndarray   # (num_sets + 1,) int64
    set_tokens: np.ndarray   # (total_tokens,)  int32
    vocab_size: int

    @property
    def num_sets(self) -> int:
        return len(self.set_indptr) - 1

    @property
    def total_tokens(self) -> int:
        return int(self.set_indptr[-1])

    @property
    def set_sizes(self) -> np.ndarray:
        return np.diff(self.set_indptr).astype(np.int32)

    def get_set(self, i: int) -> np.ndarray:
        return self.set_tokens[self.set_indptr[i]:self.set_indptr[i + 1]]

    def validate(self) -> None:
        assert self.set_indptr.ndim == 1 and self.set_tokens.ndim == 1
        assert self.set_indptr[0] == 0
        assert int(self.set_indptr[-1]) == len(self.set_tokens)
        assert np.all(np.diff(self.set_indptr) >= 0)
        if len(self.set_tokens):
            assert self.set_tokens.min() >= 0
            assert self.set_tokens.max() < self.vocab_size

    def slice_sets(self, lo: int, hi: int) -> "SetCollection":
        """Contiguous sub-collection [lo, hi) — used for partitioning."""
        base = self.set_indptr[lo]
        return SetCollection(
            set_indptr=(self.set_indptr[lo:hi + 1] - base).copy(),
            set_tokens=self.set_tokens[base:self.set_indptr[hi]].copy(),
            vocab_size=self.vocab_size,
        )


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Knobs of the KOIOS search (paper §VIII defaults: alpha=.8, k=10)."""

    k: int = 10
    alpha: float = 0.8
    # --- TPU adaptation knobs (DESIGN.md §2) ---
    chunk_size: int = 256          # stream tuples consumed per filter update
    verify_batch: int = 32         # candidate sets verified simultaneously
    # 'hungarian' = exact JV (paper-faithful; fastest on CPU hosts);
    # 'auction'/'hybrid' = batched auction with Lemma-8 dual early
    # termination — the TPU serving path (33x slower on a single CPU core:
    # EXPERIMENTS.md §Perf KOIOS-engine notes)
    verifier: str = "hungarian"
    auction_eps: float = 1e-4      # final epsilon of eps-scaling
    # 'sound' = corrected per-query-element iUB (DESIGN.md §8.5);
    # 'paper'  = the paper's Lemma-6 bound (unsound; reproduction mode only)
    ub_mode: str = "sound"
    # beyond-paper: stop the stream once no unseen set can enter the top-k
    early_stream_stop: bool = False
    # report exact SO for the returned top-k (extra verifications)
    exact_scores: bool = True
    # --- fused wave execution (DESIGN.md §3) ---
    # 'auto' = run the fused schedule on TPU, fall back to overlap
    # elsewhere; 'interpret' = force the fused wave program off-TPU
    # (Pallas interpret mode — tests/CI); 'off' = never fuse
    fused: str = "auto"
    # device verification rounds executed inside each wave program before
    # the host drive loop takes over (R in DESIGN.md §3)
    wave_rounds: int = 2
    # generate token streams with the cosine_topk Pallas kernel instead of
    # the jnp provider sweep (interpret mode off-TPU; bit-identical streams)
    stream_use_kernel: bool = False
    # refinement admission schedule (DESIGN.md §2): 'segmented' = the
    # set-segmented parallel scan (rank levels of chunk-wide vectorized
    # scatters — the default); 'serial' = the per-event reference loop.
    # Bit-identical results either way (tests/test_refinement_segmented.py)
    refine_layout: str = "segmented"

    def __post_init__(self):
        assert self.k >= 1
        assert 0.0 < self.alpha <= 1.0
        assert self.verifier in ("auction", "hungarian", "hybrid")
        assert self.ub_mode in ("sound", "paper")
        assert self.fused in ("auto", "interpret", "off")
        assert self.wave_rounds >= 0
        assert self.refine_layout in ("serial", "segmented")


@dataclasses.dataclass
class SearchStats:
    """Instrumentation mirroring the paper's Tables II/IV/V."""

    candidates: int = 0            # sets that appeared in the stream
    pruned_refinement: int = 0     # iUB/UB-filtered during refinement
    pruned_postprocess: int = 0    # UB-filtered during post-processing
    pruned_no_em: int = 0          # accepted by No-EM (no matching computed)
    pruned_em_early: int = 0       # matchings aborted by the dual bound
    exact_matches: int = 0         # full exact matchings computed
    stream_tuples: int = 0         # (q, t, sim) tuples consumed
    stream_events: int = 0         # posting-level events consumed
    refinement_chunks: int = 0
    theta_lb_final: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Top-k result: set ids, score bounds, and per-phase statistics.

    ``lb``/``ub`` bracket the true semantic overlap of each returned set;
    when ``SearchParams.exact_scores`` is set, lb == ub == SO.
    """

    ids: np.ndarray               # (k,) int32, descending score order
    lb: np.ndarray                # (k,) float32
    ub: np.ndarray                # (k,) float32
    stats: SearchStats

    @property
    def scores(self) -> np.ndarray:
        return self.lb

    def kth_score(self) -> float:
        return float(self.lb[-1]) if len(self.lb) else 0.0
