from .checkpoint import AsyncSaver, restore, save
from .collection import CollectionSnapshotter, SnapshotCorruptionError
from .manager import CheckpointManager

__all__ = ["save", "restore", "AsyncSaver", "CheckpointManager",
           "CollectionSnapshotter", "SnapshotCorruptionError"]
