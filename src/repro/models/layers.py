"""Transformer building blocks in pure JAX (no flax).

Conventions:
  * parameters are nested dicts of jnp arrays; init fns take an rng key and
    return the dict; apply fns take (params, inputs, ...);
  * all matmuls run in the config dtype (bf16 on TPU); norms, softmax and
    rope run in fp32; logits/loss in fp32;
  * attention layout: (batch, seq, heads, head_dim).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- util

def maybe_constrain(x, *spec):
    """with_sharding_constraint when a mesh is in context; identity
    otherwise (smoke tests / single-host runs have no mesh)."""
    import jax as _jax
    from jax.sharding import PartitionSpec as _P
    try:
        return _jax.lax.with_sharding_constraint(x, _P(*spec))
    except (RuntimeError, ValueError):
        return x


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return {"w": w.astype(dtype)}


def dense(params, x):
    return x @ params["w"]


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype):
    tbl = jax.random.normal(key, (vocab, d), jnp.float32) * (d ** -0.5)
    return {"table": tbl.astype(dtype)}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x):
    """Logits in fp32 (params may be the tied embedding)."""
    return (x.astype(jnp.float32) @
            params["table"].astype(jnp.float32).T)


# --------------------------------------------------------------------- rope

def rope_angles(positions, dim: int, theta: float):
    """positions (...,) -> (..., dim/2) angles."""
    freqs = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    ang = rope_angles(positions, hd, theta)          # (B, S, hd/2)
    if ang.ndim == 2:                                 # (S, hd/2)
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention

def attention_init(key, cfg, dtype, d_in: Optional[int] = None):
    d = d_in if d_in is not None else cfg.d_model
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _sdpa(q, k, v, mask):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd), expanded to H by head-map gather.

    §Perf note (EXPERIMENTS.md, tinyllama iterations 1-2): a grouped-einsum
    formulation (q reshaped to (KV, rep)) was tried and REFUTED — neither
    (KV) nor (rep) divides a 16-way model axis for the GQA archs, so GSPMD
    resharded every layer regardless.  The working layout: KV projections
    replicated over the model axis when KV heads don't shard cleanly
    (runtime/sharding.py head-granular rules) and the head expansion done
    by gather from the replicated source, which partitions on the expanded
    H dim."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    h_to_g = jnp.arange(H) // (H // KV)
    k = jnp.take(k, h_to_g, axis=2)
    v = jnp.take(v, h_to_g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention(params, cfg, x, positions, *, mask=None, cache=None,
              cache_index=None, x_kv=None):
    """GQA/MQA attention.

    Training/prefill: x (B,S,d), causal mask, returns (out, new_cache or None).
    Decode: x (B,1,d), cache = dict(k,v: (B,Smax,KV,hd)), cache_index scalar
    step; writes the new KV at cache_index and attends over [0, cache_index].
    Cross-attention: pass x_kv (B,Sk,d) and mask=None (full visibility);
    cache then holds the static encoder KV.
    """
    hd = cfg.hd
    B, S, _ = x.shape
    q = dense(params["wq"], x).reshape(B, S, cfg.num_heads, hd)
    src = x if x_kv is None else x_kv
    k = dense(params["wk"], src).reshape(B, src.shape[1], cfg.num_kv_heads, hd)
    v = dense(params["wv"], src).reshape(B, src.shape[1], cfg.num_kv_heads, hd)

    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)

    if x_kv is None:  # self-attention: rope
        q = apply_rope(q, positions, cfg.rope_theta)
        kv_pos = positions if cache is None else positions
        k = apply_rope(k, kv_pos, cfg.rope_theta)

    if cache is not None and cache_index is not None:
        # decode: append at cache_index
        k_all = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        Smax = k_all.shape[1]
        visible = jnp.arange(Smax)[None, None, None, :] <= cache_index
        out = _sdpa(q, k_all, v_all, visible)
        new_cache = {"k": k_all, "v": v_all}
    elif x_kv is not None:
        # cross-attention (full visibility over encoder states)
        Sk = src.shape[1]
        full = jnp.ones((1, 1, S, Sk), bool)
        out = _sdpa(q, k, v, full)
        new_cache = None
    else:
        if mask is None:
            mask = jnp.tril(jnp.ones((S, S), bool))[None, None]
        if getattr(cfg, "attn_seq_parallel", False):
            # context parallelism: queries sharded over 'model' along S,
            # compact K/V replicated over 'model' (gathered once; the
            # (S, S) score tile shrinks by the TP degree) — §Perf llama4.
            q = maybe_constrain(q, "data", "model", None, None)
            k = maybe_constrain(k, "data", None, None, None)
            v = maybe_constrain(v, "data", None, None, None)
        out = _sdpa(q, k, v, mask)
        if getattr(cfg, "attn_seq_parallel", False):
            out = maybe_constrain(out, "data", "model", None, None)
        new_cache = {"k": k, "v": v}
    out = out.reshape(B, S, cfg.num_heads * hd)
    out = dense(params["wo"], out)
    if x_kv is None and cache is None and \
            getattr(cfg, "attn_seq_parallel", False):
        out = maybe_constrain(out, "data", None, None)   # restore layout
    return out, new_cache


# --------------------------------------------------------------------- mlp

def swiglu_init(key, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, d_ff, dtype),
        "w_up": dense_init(ks[1], d, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d, dtype),
    }


def swiglu(params, x):
    g = jax.nn.silu(dense(params["w_gate"], x).astype(jnp.float32))
    u = dense(params["w_up"], x).astype(jnp.float32)
    return dense(params["w_down"], (g * u).astype(x.dtype))


# -------------------------------------------------------------------- loss

def softmax_xent(logits, labels, mask=None):
    """Mean next-token cross entropy in fp32.  labels -100 => ignored."""
    valid = labels >= 0 if mask is None else mask
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


def blocked_xent(x, head_table, labels, block: int = 8192):
    """Cross entropy WITHOUT materializing the (B, S, V) logits.

    §Perf optimization (EXPERIMENTS.md): the fused head-matmul+loss scans
    the vocabulary in blocks of ``block`` columns, keeping a running
    streaming logsumexp and gathering the gold logit on the fly; each block
    body is rematerialized in the backward pass.  Peak logits memory drops
    from O(B*S*V) fp32 (67 GB/device for the 256k-vocab archs at train_4k)
    to O(B*S*block).

    x: (B, S, d) final hidden states;  head_table: (V, d);  labels (B, S).
    """
    B, S, d = x.shape
    V = head_table.shape[0]
    pad = (-V) % block
    n_blocks = (V + pad) // block
    if pad:   # dynamic_slice clamps at the boundary — pad explicitly
        head_table = jnp.pad(head_table, ((0, pad), (0, 0)))
    xf = x.astype(jnp.float32).reshape(B * S, d)
    valid = labels >= 0
    lab = jnp.maximum(labels, 0).reshape(B * S)

    def body(carry, i):
        m, lse, gold = carry
        tbl = jax.lax.dynamic_slice_in_dim(
            head_table, i * block, block, axis=0).astype(jnp.float32)
        logits = xf @ tbl.T                                   # (BS, block)
        cols = i * block + jnp.arange(block)
        logits = jnp.where(cols[None, :] < V, logits, -1e30)
        bmax = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m, bmax)
        lse = jnp.exp(m - new_m) * lse + jnp.sum(
            jnp.exp(logits - new_m[:, None]), axis=-1)
        hit = (lab >= i * block) & (lab < (i + 1) * block)
        local = jnp.take_along_axis(
            logits, jnp.clip(lab - i * block, 0, block - 1)[:, None],
            axis=1)[:, 0]
        gold = jnp.where(hit, local, gold)
        return (new_m, lse, gold), None

    init = (jnp.full((B * S,), -1e30), jnp.zeros((B * S,)),
            jnp.zeros((B * S,)))
    (m, lse, gold), _ = jax.lax.scan(jax.checkpoint(body), init,
                                     jnp.arange(n_blocks))
    logz = m + jnp.log(jnp.maximum(lse, 1e-30))
    nll = (logz - gold).reshape(B, S) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
