"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine_topk_ref(qe: jnp.ndarray, ev: jnp.ndarray, k: int):
    """Full-matrix cosine scores + top-k per query row.

    qe: (nq, d) L2-normalized query embeddings.
    ev: (nv, d) L2-normalized vocabulary embeddings.
    Returns (vals (nq, k), idx (nq, k)) descending.
    """
    scores = qe @ ev.T
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def compact_indices_ref(mask: jnp.ndarray):
    """Prefix-sum compaction oracle: survivor indices ascending, -1 pad.

    mask: (n,) bool.  Returns (idx (n,) int32, count () int32) with
    idx[:count] == mask.nonzero()[0] and idx[count:] == -1.
    """
    n = mask.shape[0]
    m = mask.astype(jnp.int32)
    ps = jnp.cumsum(m)
    total = ps[-1] if n else jnp.int32(0)
    iota = jnp.arange(n, dtype=jnp.int32)
    pos = jnp.where(m > 0, ps - 1, total + iota - ps)
    idx = jnp.full((n,), -1, jnp.int32).at[pos].set(
        jnp.where(m > 0, iota, jnp.int32(-1)))
    return idx, total.astype(jnp.int32)


def auction_topk2_ref(wm: jnp.ndarray, prices: jnp.ndarray):
    """Per-row best/second-best profit and best column (one auction round's
    heavy pass).  wm: (n, m); prices: (m,).  Returns (w1, w2, jstar)."""
    profits = wm - prices[None, :]
    w1 = jnp.max(profits, axis=1)
    jstar = jnp.argmax(profits, axis=1).astype(jnp.int32)
    cols = jnp.arange(wm.shape[1])
    second = jnp.where(cols[None, :] == jstar[:, None], -jnp.inf, profits)
    w2 = jnp.max(second, axis=1)
    return w1, w2, jstar


def ssd_ref(x, dt, A, B, C, D, chunk: int = 0):
    """Mamba2 SSD (state-space duality) sequential-scan oracle.

    Shapes (single sequence):
      x:  (L, H, P)    input heads (P = head dim)
      dt: (L, H)       softplus-ed timestep per head
      A:  (H,)         negative state decay per head (A < 0)
      B:  (L, G, S)    input->state projection (G state groups, S = state dim)
      C:  (L, G, S)    state->output projection
      D:  (H,)         skip connection
    Heads are grouped: head h uses group h % G.
    Returns y: (L, H, P).

    Recurrence (per head h, group g = h % G):
      S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t (outer) x_t
      y_t = C_t . S_t + D_h * x_t
    """
    L, H, P = x.shape
    G = B.shape[1]
    S = B.shape[2]

    def step(carry, t):
        st = carry                                 # (H, P, S)
        dta = jnp.exp(dt[t][:, None, None] * A[:, None, None])  # (H,1,1)
        Bg = B[t][jnp.arange(H) % G]               # (H, S)
        Cg = C[t][jnp.arange(H) % G]               # (H, S)
        upd = dt[t][:, None, None] * x[t][:, :, None] * Bg[:, None, :]
        st = dta * st + upd                        # (H, P, S)
        y = jnp.einsum("hps,hs->hp", st, Cg) + D[:, None] * x[t]
        return st, y

    st0 = jnp.zeros((H, P, S), x.dtype)
    _, ys = jax.lax.scan(step, st0, jnp.arange(L))
    return ys


def flash_attention_ref(q, k, v, causal: bool = True):
    """Dense softmax(QK^T/sqrt(d))V oracle.  q,k,v: (B,H,S,d)."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * d ** -0.5
    if causal:
        S = q.shape[2]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
