"""Mamba2 block (state-space duality) — pure-jnp chunked SSD + decode step.

The training/prefill path uses the chunked SSD formulation (quadratic within
a chunk — MXU matmuls — linear across chunks); it is mathematically the same
computation as ``repro.kernels.ssd_scan`` (the Pallas TPU kernel) and is the
path the dry-run lowers so XLA cost analysis stays truthful (DESIGN.md §7).
Decode is the O(1) recurrence over (H, P, S) state + a (conv_width-1) FIFO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, SSMConfig
from .layers import dense, dense_init, rmsnorm, rmsnorm_init


# ------------------------------------------------------------ chunked SSD

def ssd_jnp(x, dt, A, Bm, Cm, D, chunk: int):
    """Batched SSD.  x (B,L,H,P), dt (B,L,H), A (H,), Bm/Cm (B,L,G,S), D (H,).

    Returns (y (B,L,H,P), final_state (B,H,P,S)).
    """
    Bt, L, H, P = x.shape
    G, S = Bm.shape[2], Bm.shape[3]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    T = chunk
    rep = H // G

    xc = x.reshape(Bt, nc, T, H, P)
    dtc = dt.reshape(Bt, nc, T, H)
    Bc = Bm.reshape(Bt, nc, T, G, S)
    Cc = Cm.reshape(Bt, nc, T, G, S)

    a = dtc * A[None, None, None, :]                    # (B,nc,T,H) log-decay
    cum = jnp.cumsum(a, axis=2)                         # inclusive

    # ---- intra-chunk (quadratic, attention-like) --------------------------
    hg = jnp.arange(H) % G                              # head -> group (ref.py)
    cb = jnp.einsum("bntgs,bnugs->bngtu", Cc, Bc)       # (B,nc,G,T,T)
    cb = jnp.take(cb, hg, axis=2)                       # (B,nc,H,T,T)
    cumh = cum.transpose(0, 1, 3, 2)                    # (B,nc,H,T)
    # gate[b,n,h,t,u] = exp(cum[t] - cum[u]), masked to u <= t.  The mask
    # must be applied INSIDE the exp: for u > t the difference is large and
    # positive, exp overflows, and where() would leak NaN into the backward
    # pass (0 * inf).
    tril = jnp.tril(jnp.ones((T, T), bool))
    diff = cumh[..., :, None] - cumh[..., None, :]
    gate = jnp.exp(jnp.where(tril[None, None, None], diff, -1e30))
    dx = dtc[..., None] * xc                            # (B,nc,T,H,P)
    y_intra = jnp.einsum("bnhtu,bnuhp->bnthp", cb * gate, dx)

    # ---- chunk states ------------------------------------------------------
    w = dtc * jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,T,H)
    Bh = jnp.take(Bc, hg, axis=3)                       # (B,nc,T,H,S)
    chunk_state = jnp.einsum("bnth,bnthp,bnths->bnhps", w, xc, Bh)
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (B,nc,H)

    # ---- inter-chunk scan --------------------------------------------------
    def step(carry, inp):
        st = carry                                      # (B,H,P,S)
        decay, cs = inp
        new = decay[:, :, None, None] * st + cs
        return new, st                                  # emit state *before*

    init = jnp.zeros((Bt, H, P, S), x.dtype)
    final, prev_states = jax.lax.scan(
        step, init,
        (chunk_decay.swapaxes(0, 1), chunk_state.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)            # (B,nc,H,P,S)

    Ch = jnp.take(Cc, hg, axis=3)                       # (B,nc,T,H,S)
    y_inter = jnp.einsum("bnth,bnths,bnhps->bnthp",
                         jnp.exp(cum), Ch, prev_states)

    y = y_intra + y_inter + D[None, None, None, :, None] * xc
    y = y.reshape(Bt, Lp, H, P)[:, :L]
    return y, final


# -------------------------------------------------------------- full block

def mamba2_init(key, cfg: ModelConfig, dtype):
    """Separate z/x/B/C/dt projections + per-component causal convs.

    The reference implementation fuses these into one in_proj and one
    conv over concat(x, B, C); we keep them separate so each output dim
    shards cleanly over the TP axis (DESIGN.md §5 — the fused layout has
    a 2*inner+2*G*S+H output dim that is generally not divisible by the
    mesh and whose split points fall inside shards).  FLOPs/params are
    identical.
    """
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    H = inner // s.head_dim
    G = s.ngroups
    gs = G * s.state_dim
    ks = jax.random.split(key, 7)

    def conv(k, dim):
        w = jax.random.normal(k, (s.conv_width, dim), jnp.float32)
        return (w * (s.conv_width ** -0.5)).astype(dtype)

    return {
        "z_proj": dense_init(ks[0], d, inner, dtype),
        "x_proj": dense_init(ks[1], d, inner, dtype),
        "b_proj": dense_init(ks[2], d, gs, dtype),
        "c_proj": dense_init(ks[3], d, gs, dtype),
        "dt_proj": dense_init(ks[4], d, H, dtype),
        "conv_x_w": conv(ks[5], inner),
        "conv_x_b": jnp.zeros((inner,), dtype),
        "conv_b_w": conv(ks[6], gs),
        "conv_b_b": jnp.zeros((gs,), dtype),
        "conv_c_w": conv(jax.random.fold_in(ks[6], 1), gs),
        "conv_c_b": jnp.zeros((gs,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": rmsnorm_init(inner, dtype),
        "out_proj": dense_init(jax.random.fold_in(ks[5], 7), inner, d, dtype),
    }


def _causal_conv(xs, w, b):
    """Depthwise causal conv, width K: y_t = sum_k w_k x_{t-K+1+k}."""
    K = w.shape[0]
    pads = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pads[:, k:k + xs.shape[1], :] * w[k][None, None, :]
            for k in range(K))
    return jax.nn.silu((y + b[None, None, :]).astype(jnp.float32)).astype(
        xs.dtype)


def mamba2_block(params, cfg: ModelConfig, x):
    """Full-sequence forward.  x (B,L,d) -> (y (B,L,d), cache)."""
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    H = inner // s.head_dim
    G, S = s.ngroups, s.state_dim
    B_, L, _ = x.shape
    K = s.conv_width

    z = dense(params["z_proj"], x)
    x_raw = dense(params["x_proj"], x)
    b_raw = dense(params["b_proj"], x)
    c_raw = dense(params["c_proj"], x)
    dt = dense(params["dt_proj"], x)

    xc = _causal_conv(x_raw, params["conv_x_w"], params["conv_x_b"])
    bc = _causal_conv(b_raw, params["conv_b_w"], params["conv_b_b"])
    cc = _causal_conv(c_raw, params["conv_c_w"], params["conv_c_b"])
    xin = xc.reshape(B_, L, H, s.head_dim)
    Bm = bc.reshape(B_, L, G, S)
    Cm = cc.reshape(B_, L, G, S)

    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    y, final_state = ssd_jnp(xin.astype(jnp.float32), dtp, A,
                             Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                             params["D"], s.chunk)
    y = y.reshape(B_, L, inner).astype(x.dtype)
    y = rmsnorm(params["gate_norm"],
                y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = dense(params["out_proj"], y)

    def fifo(raw):
        pad = jnp.pad(raw, ((0, 0), (max(0, K - 1 - L), 0), (0, 0)))
        return pad[:, -(K - 1):, :]

    cache = {"ssm": final_state.astype(jnp.float32),
             "cx": fifo(x_raw), "cb": fifo(b_raw), "cc": fifo(c_raw)}
    return out, cache


def _conv_step(fifo, new, w, b):
    """One causal-conv step over FIFO+current; returns (y, new_fifo)."""
    window = jnp.concatenate([fifo, new], axis=1)          # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32))
    y = jax.nn.silu(y + b.astype(jnp.float32))[:, None, :]
    return y.astype(new.dtype), window[:, 1:, :]


def mamba2_decode(params, cfg: ModelConfig, x, cache):
    """Single-token step.  x (B,1,d);
    cache {ssm (B,H,P,S), cx (B,K-1,inner), cb/cc (B,K-1,G*S)}."""
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    H = inner // s.head_dim
    G, S = s.ngroups, s.state_dim
    B_, _, _ = x.shape

    z = dense(params["z_proj"], x)
    x_raw = dense(params["x_proj"], x)
    b_raw = dense(params["b_proj"], x)
    c_raw = dense(params["c_proj"], x)
    dt = dense(params["dt_proj"], x)

    xc, new_cx = _conv_step(cache["cx"], x_raw, params["conv_x_w"],
                            params["conv_x_b"])
    bc, new_cb = _conv_step(cache["cb"], b_raw, params["conv_b_w"],
                            params["conv_b_b"])
    cc_, new_cc = _conv_step(cache["cc"], c_raw, params["conv_c_w"],
                             params["conv_c_b"])

    xin = xc[:, 0].reshape(B_, H, s.head_dim)
    Bm = bc[:, 0].reshape(B_, G, S)
    Cm = cc_[:, 0].reshape(B_, G, S)
    hg = jnp.arange(H) % G
    Bh = jnp.take(Bm, hg, axis=1)                       # (B,H,S)
    Ch = jnp.take(Cm, hg, axis=1)

    dtp = jax.nn.softplus(dt.astype(jnp.float32)[:, 0]
                          + params["dt_bias"][None, :])   # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dtp * A[None, :])                     # (B,H)
    st = cache["ssm"]
    st = (decay[:, :, None, None] * st
          + dtp[:, :, None, None] * xin.astype(jnp.float32)[:, :, :, None]
          * Bh.astype(jnp.float32)[:, :, None, :])
    y = jnp.einsum("bhps,bhs->bhp", st, Ch.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(B_, 1, inner).astype(x.dtype)
    y = rmsnorm(params["gate_norm"],
                y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = dense(params["out_proj"], y)
    return out, {"ssm": st, "cx": new_cx, "cb": new_cb, "cc": new_cc}
