"""Set-segmented refinement scan + device-resident event expansion
(the PR-5 tentpole): the segmented admission schedule and the fused
wave's in-trace expansion are bit-identical to the serial host path,
and the cross-set commutativity the layout rests on holds as a
property."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (InvertedIndex, KoiosSearch, SearchParams,
                        build_token_stream, expand_to_events)
from repro.core.refinement import refine_carry_init, refine_chunk_step, \
    run_refinement
from repro.core.token_stream import (EventStream, event_ranks,
                                     pack_events_segmented, pad_events)
from repro.data import sample_queries


def _valid_events(rng, n_events: int, num_sets: int, nq: int,
                  slots_per_set: int = 8) -> EventStream:
    """Synthetic posting-level events honouring the domain invariant the
    segmented layout rests on: each flat slot belongs to exactly one set."""
    set_id = rng.integers(0, num_sets, n_events).astype(np.int32)
    return EventStream(
        set_id=set_id,
        q_pos=rng.integers(0, nq, n_events).astype(np.int32),
        slot=(set_id * slots_per_set
              + rng.integers(0, slots_per_set, n_events)).astype(np.int32),
        sim=np.sort(rng.random(n_events).astype(np.float32))[::-1],
        n_tuples=n_events)


@pytest.mark.parametrize("ub_mode", ["sound", "paper"])
@pytest.mark.parametrize("chunk", [16, 64, 256])
def test_segmented_matches_serial_bitwise(small_world, ub_mode, chunk):
    """The tentpole guarantee at the scan level: the lane-packed
    segmented admission returns the same floats, bounds, masks, and
    theta as the per-event serial loop, at every chunk size and in both
    ub modes."""
    coll, sim = small_world
    inv = InvertedIndex.build(coll)
    for seed in (3, 11):
        q = sample_queries(coll, 1, seed=seed)[0]
        ev = expand_to_events(build_token_stream(q, sim, 0.8), inv)
        a = run_refinement(ev, coll.set_sizes, len(q), coll.total_tokens,
                           5, 0.8, chunk, ub_mode, layout="serial")
        b = run_refinement(ev, coll.set_sizes, len(q), coll.total_tokens,
                           5, 0.8, chunk, ub_mode, layout="segmented")
        assert np.array_equal(a.S, b.S)
        assert np.array_equal(a.ub, b.ub)
        assert np.array_equal(a.seen, b.seen)
        assert np.array_equal(a.alive, b.alive)
        assert a.theta_lb == b.theta_lb
        assert a.stats.pruned_refinement == b.stats.pruned_refinement


@pytest.mark.parametrize("partitions", [1, 2, 4])
def test_segmented_engine_bitwise(small_world, partitions):
    """End-to-end: an engine on the segmented layout returns results
    bit-identical to the serial layout on every schedule."""
    coll, sim = small_world
    queries = sample_queries(coll, 4, seed=5)
    results = {}
    for layout in ("serial", "segmented"):
        params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                              refine_layout=layout)
        engine = KoiosSearch(coll, sim, params, partitions=partitions)
        for schedule in ("sequential", "overlap"):
            results[(layout, schedule)] = engine.search_batch(
                queries, schedule=schedule)
    base = results[("serial", "sequential")]
    for key, rs in results.items():
        for a, b in zip(base, rs):
            assert np.array_equal(a.ids, b.ids), key
            assert np.array_equal(a.lb, b.lb), key
            assert np.array_equal(a.ub, b.ub), key


@pytest.mark.parametrize("layout", ["serial", "segmented"])
def test_fused_device_expansion_bitwise(small_world, layout):
    """The fused wave consumes the compact stream and expands in-trace
    (DESIGN.md §3.3); with either embedded admission layout the results
    must equal the host path bit for bit."""
    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          fused="interpret", refine_layout=layout)
    engine = KoiosSearch(coll, sim, params, partitions=2)
    queries = sample_queries(coll, 4, seed=5)
    seq = engine.search_batch(queries, schedule="sequential")
    fus = engine.search_batch(queries, schedule="fused")
    assert engine.scheduler_stats.schedule == "fused"
    for a, b in zip(seq, fus):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.lb, b.lb)
        assert np.array_equal(a.ub, b.ub)


def test_expand_events_traced_mirrors_host(small_world):
    """The searchsorted-on-cumsum gather reproduces
    ``expand_to_events`` + ``pad_events`` bit for bit — including extra
    pad chunks and the empty-stream 0.0 pad."""
    from repro.core.wave import expand_events_traced

    coll, sim = small_world
    inv = InvertedIndex.build(coll)
    dev = inv.device_arrays()
    chunk = 64
    for seed in (3, 5):
        q = sample_queries(coll, 1, seed=seed)[0]
        stream = build_token_stream(q, sim, 0.8)
        host = pad_events(expand_to_events(stream, inv), chunk)
        n_chunks = host[0].shape[0] * 2      # extra all-pad chunks too
        tok = np.full(128, -1, np.int32)
        qp = np.zeros(128, np.int32)
        sm = np.zeros(128, np.float32)
        tok[:len(stream)] = stream.token
        qp[:len(stream)] = stream.q_pos
        sm[:len(stream)] = stream.sim
        es, eq, esl, esim = [np.asarray(x) for x in expand_events_traced(
            jnp.asarray(tok), jnp.asarray(qp), jnp.asarray(sm),
            *dev, n_chunks, chunk)]
        n = host[0].shape[0]
        assert np.array_equal(es[:n], host[0])
        assert np.array_equal(eq[:n], host[1])
        assert np.array_equal(esl[:n], host[2])
        assert np.array_equal(esim[:n], host[3])
        # extra pad chunks: sentinel sets, final-sim fill
        assert np.all(es[n:] == -1)
        assert np.all(esim[n:] == host[3][-1, -1])
    # empty stream: no postings, sims pad 0.0 (the pad_events fix)
    empty = expand_events_traced(
        jnp.full(8, -1, jnp.int32), jnp.zeros(8, jnp.int32),
        jnp.zeros(8, jnp.float32), *dev, 1, chunk)
    assert np.all(np.asarray(empty[0]) == -1)
    assert np.all(np.asarray(empty[3]) == 0.0)


def test_empty_stream_full_scan():
    """Regression (PR-5 satellite): an empty stream pads sims with 0.0,
    and the full refinement scan is inert on it in both layouts."""
    empty = EventStream(set_id=np.zeros(0, np.int32),
                        q_pos=np.zeros(0, np.int32),
                        slot=np.zeros(0, np.int32),
                        sim=np.zeros(0, np.float32), n_tuples=0)
    padded = pad_events(empty, 16)
    assert padded[0].shape == (1, 16)
    assert np.all(padded[0] == -1)
    assert np.all(padded[3] == 0.0)          # NOT the historical 1.0
    sizes = np.full(10, 4, np.int32)
    for layout in ("serial", "segmented"):
        r = run_refinement(empty, sizes, 4, 40, 3, 0.8, 16, "sound",
                           layout=layout)
        assert not r.seen.any()
        assert r.alive.all()
        assert r.theta_lb == 0.0
        assert np.all(r.S == 0.0)
        assert r.stats.pruned_refinement == 0


def _admission_fields(state):
    """Carry fields written by admission (everything except alive and
    theta, which the chunk filter pass owns)."""
    S, l, T, d, seen, alive, qm, qs, sm, theta = state
    return [np.asarray(x) for x in (S, l, T, d, seen, qm, qs, sm)]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(20, 200))
def test_cross_set_permutation_leaves_carry_bit_identical(seed, n_events):
    """THE invariant the segmented layout rests on: permuting a chunk's
    events across sets — while preserving each set's own order — leaves
    the admission carry bit-identical, because every mutated field is
    per-set and each flat slot belongs to exactly one set."""
    rng = np.random.default_rng(seed)
    num_sets, nq, chunk = 12, 16, 256
    ev = _valid_events(rng, n_events, num_sets, nq)
    es, eq, esl, esim = pad_events(ev, chunk)
    # cross-set permutation of chunk 0: stable sort by a random per-set
    # key (ties keep stream order, so within-set order is preserved)
    key = rng.permutation(num_sets + 1)
    perm = np.argsort(key[es[0] + 1], kind="stable")
    assert (es[0].min() == -1) or len(set(es[0])) == 1 or \
        not np.array_equal(perm, np.arange(chunk)) or n_events < 2

    cap = jnp.full((num_sets,), min(nq, 8), jnp.int32)
    state0 = refine_carry_init(num_sets, 1, num_sets * 8)
    out_a, _ = refine_chunk_step(
        state0, (jnp.asarray(es[0]), jnp.asarray(eq[0]),
                 jnp.asarray(esl[0]), jnp.asarray(esim[0])),
        cap, 3, "sound")
    out_b, _ = refine_chunk_step(
        state0, (jnp.asarray(es[0][perm]), jnp.asarray(eq[0][perm]),
                 jnp.asarray(esl[0][perm]), jnp.asarray(esim[0][perm])),
        cap, 3, "sound")
    for a, b in zip(_admission_fields(out_a), _admission_fields(out_b)):
        assert np.array_equal(a, b)


def test_event_ranks_are_within_set_occurrence_indices():
    """Host ranks == traced ranks == the occurrence index of each event
    within its (chunk, set) segment."""
    from repro.kernels.ref import event_ranks_ref

    rng = np.random.default_rng(7)
    ev = _valid_events(rng, 300, 9, 8)
    es = pad_events(ev, 64)[0]
    ranks = event_ranks(es)
    for c in range(es.shape[0]):
        # brute-force occurrence index
        counts = {}
        for j, s in enumerate(es[c]):
            expect = counts.get(s, 0)
            counts[s] = expect + 1
            assert ranks[c, j] == expect, (c, j, s)
        traced = np.asarray(event_ranks_ref(jnp.asarray(es[c])))
        assert np.array_equal(traced, ranks[c])


def test_pack_events_segmented_layout():
    """Lane packing invariants: every valid event appears exactly once,
    rows hold pairwise-distinct sets, and row index == within-set rank."""
    rng = np.random.default_rng(3)
    ev = _valid_events(rng, 500, 17, 8)
    padded = pad_events(ev, 128)
    s3, q3, sl3, si3, snow = pack_events_segmented(*padded)
    assert np.array_equal(snow, padded[3][:, -1])
    n_chunks = padded[0].shape[0]
    W, L = s3.shape[1], s3.shape[2]
    assert W & (W - 1) == 0 and L & (L - 1) == 0
    total_valid = int((padded[0] >= 0).sum())
    assert int((s3 >= 0).sum()) == total_valid
    for c in range(n_chunks):
        for t in range(W):
            row = s3[c, t][s3[c, t] >= 0]
            assert len(np.unique(row)) == len(row)   # distinct sets per row
        # row index is the within-set rank: counting occurrences of a set
        # down the rows reproduces its segment length
        flat = padded[0][c]
        for s in np.unique(flat[flat >= 0]):
            seg = int((flat == s).sum())
            rows_with_s = [t for t in range(W) if s in s3[c, t]]
            assert rows_with_s == list(range(seg))
