"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and KV are projected through low-rank bottlenecks; only the
compressed KV latent (kv_lora_rank) plus a shared rope key (qk_rope_dim) is
cached.  Decode uses the *absorbed* formulation: the per-head up-projections
fold into the query/output sides, so decoding attends MQA-style over the
(S, kv_lora + rope) cache — the memory win that makes 32k/500k KV caches
feasible (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import MLAConfig, ModelConfig
from .layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init


def mla_init(key, cfg: ModelConfig, dtype):
    m: MLAConfig = cfg.mla
    H = cfg.num_heads
    ks = jax.random.split(key, 6)
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_down": dense_init(ks[0], cfg.d_model, m.q_lora_rank, dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "wq_up": dense_init(ks[1], m.q_lora_rank, H * qk, dtype),
        "wkv_down": dense_init(ks[2], cfg.d_model,
                               m.kv_lora_rank + m.qk_rope_dim, dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "wkv_up": dense_init(ks[3], m.kv_lora_rank,
                             H * (m.qk_nope_dim + m.v_dim), dtype),
        "wo": dense_init(ks[4], H * m.v_dim, cfg.d_model, dtype),
    }


def _project_q(params, cfg, x, positions):
    m = cfg.mla
    H = cfg.num_heads
    B, S, _ = x.shape
    q_lat = rmsnorm(params["q_norm"], dense(params["wq_down"], x))
    q = dense(params["wq_up"], q_lat).reshape(
        B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params, cfg, x, positions):
    m = cfg.mla
    kv = dense(params["wkv_down"], x)
    ckv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    ckv = rmsnorm(params["kv_norm"], ckv)
    # shared-across-heads rope key: (B, S, 1, rope) for rope application
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return ckv, k_rope


def mla_attention(params, cfg: ModelConfig, x, positions, mask=None):
    """Training/prefill path (full materialization).  Returns (out, cache)."""
    m = cfg.mla
    H = cfg.num_heads
    B, S, _ = x.shape
    q_nope, q_rope = _project_q(params, cfg, x, positions)
    ckv, k_rope = _project_kv_latent(params, cfg, x, positions)

    kv = dense(params["wkv_up"], ckv).reshape(
        B, S, H, m.qk_nope_dim + m.v_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_dim], axis=-1)

    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
              ).astype(jnp.float32) * scale
    if mask is None:
        mask = jnp.tril(jnp.ones((S, S), bool))[None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    out = dense(params["wo"], out.reshape(B, S, H * m.v_dim))
    return out, {"ckv": ckv, "k_rope": k_rope}


def mla_decode(params, cfg: ModelConfig, x, cache, cache_index, positions):
    """Absorbed MQA-style decode over the compressed cache.

    cache: {ckv (B, Smax, kv_lora), k_rope (B, Smax, rope)}.
    """
    m = cfg.mla
    H = cfg.num_heads
    B, S, _ = x.shape                     # S == 1
    q_nope, q_rope = _project_q(params, cfg, x, positions)
    ckv_new, k_rope_new = _project_kv_latent(params, cfg, x, positions)
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, cache_index, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
        (0, cache_index, 0))

    # absorb kv_up nope block into q:  q' = q_nope @ W_uk^T  -> latent space
    wkv = params["wkv_up"]["w"].reshape(
        m.kv_lora_rank, H, m.qk_nope_dim + m.v_dim)
    w_uk = wkv[:, :, :m.qk_nope_dim]                    # (lora, H, nope)
    w_uv = wkv[:, :, m.qk_nope_dim:]                    # (lora, H, v)
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk)  # (B,1,H,lora)

    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    logits = (jnp.einsum("bqhl,bkl->bhqk", q_lat, ckv)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
              ).astype(jnp.float32) * scale
    Smax = ckv.shape[1]
    visible = jnp.arange(Smax)[None, None, None, :] <= cache_index
    logits = jnp.where(visible, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqk,bkl->bqhl", probs, ckv)    # (B,1,H,lora)
    out = jnp.einsum("bqhl,lhd->bqhd", o_lat, w_uv)     # absorb v-up
    out = dense(params["wo"], out.reshape(B, S, H * m.v_dim))
    return out, {"ckv": ckv, "k_rope": k_rope}
