"""minitron-8b [dense] — pruned nemotron; huge vocab.

Assigned: 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
[arXiv:2407.14679; hf]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=16384, vocab_size=256000)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=1024,
        dtype="float32", remat="none")
