"""MoE dispatch-implementation equivalence + routing behaviour."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.config import MoEConfig
from repro.models.moe import moe_ffn, moe_init


def _setup(seed, d=16, E=4, K=2, dff=8, T=24, shared=1):
    m = MoEConfig(num_experts=E, top_k=K, d_ff_expert=dff,
                  num_shared=shared)
    params = moe_init(jax.random.key(seed), d, m, jnp.float32)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(2, T // 2, d)),
                    jnp.float32)
    return m, params, x


@pytest.mark.parametrize("impl", ["dispatch", "gather"])
def test_impls_match_ragged_when_capacity_nonbinding(impl):
    for seed in range(3):
        m, params, x = _setup(seed)
        m2 = dataclasses.replace(m, impl=impl, capacity_factor=8.0)
        y1, a1 = moe_ffn(params, x, m)
        y2, a2 = moe_ffn(params, x, m2)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-5)
        np.testing.assert_allclose(float(a1["lb_loss"]),
                                   float(a2["lb_loss"]), rtol=1e-6)


def test_capacity_drops_reduce_output_norm():
    """Binding capacity drops tokens: output differs from dropless but
    remains finite (production dropping semantics)."""
    m, params, x = _setup(0, T=32)
    tight = dataclasses.replace(m, impl="gather", capacity_factor=0.25)
    y1, _ = moe_ffn(params, x, m)
    y2, _ = moe_ffn(params, x, tight)
    assert np.all(np.isfinite(np.asarray(y2)))
    assert not np.allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 3), st.sampled_from([2, 4, 8]))
def test_moe_grads_finite(seed, K, E):
    K = min(K, E)
    m = MoEConfig(num_experts=E, top_k=K, d_ff_expert=8, num_shared=0)
    params = moe_init(jax.random.key(seed), 8, m, jnp.float32)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(1, 6, 8)),
                    jnp.float32)

    def loss(p):
        y, aux = moe_ffn(p, x, m)
        return jnp.sum(y * y) + aux["lb_loss"]

    g = jax.grad(loss)(params)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in jax.tree_util.tree_leaves(g))


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives lb_loss ~= 1 (Switch normalization)."""
    m, params, x = _setup(1, E=4, K=1, T=64)
    # force uniform router
    params["router"]["w"] = jnp.zeros_like(params["router"]["w"])
    _, aux = moe_ffn(params, x, dataclasses.replace(m, top_k=1))
    assert 0.9 < float(aux["lb_loss"]) < 1.6
