from .registry import ARCHS, get_config, get_smoke_config, list_archs

__all__ = ["ARCHS", "get_config", "get_smoke_config", "list_archs"]
