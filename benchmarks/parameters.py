"""Paper Fig. 7: parameter analysis — response time vs (a) partitions,
(b) element-similarity threshold alpha, (c) result size k; (d) memory vs
alpha."""
from __future__ import annotations

import numpy as np

from repro.core import KoiosSearch, SearchParams
from repro.data import sample_queries

from .common import memory_footprint_bytes, timed, world


def run(dataset="opendata", n_queries=2,
        partitions=(1, 2, 4), alphas=(0.7, 0.8, 0.9), ks=(1, 10, 50)):
    coll, sim = world(dataset)
    queries = sample_queries(coll, n_queries, seed=17)
    out = {"partitions": [], "alpha": [], "k": []}

    for p in partitions:
        engine = KoiosSearch(coll, sim, SearchParams(k=10, alpha=0.8),
                             partitions=p)
        t = sum(timed(engine.search, q)[1] for q in queries) / len(queries)
        out["partitions"].append({"partitions": p, "time_s": t})

    for a in alphas:
        engine = KoiosSearch(coll, sim, SearchParams(k=10, alpha=a))
        t = 0.0
        em = 0
        for q in queries:
            r, dt = timed(engine.search, q)
            t += dt
            em += r.stats.exact_matches
        out["alpha"].append({
            "alpha": a, "time_s": t / len(queries),
            "em": em / len(queries),
            "mem_mb": memory_footprint_bytes(
                dataset, int(np.mean([len(q) for q in queries])))["total"]
            / 1e6})

    for k in ks:
        engine = KoiosSearch(coll, sim, SearchParams(k=k, alpha=0.8))
        t = sum(timed(engine.search, q)[1] for q in queries) / len(queries)
        out["k"].append({"k": k, "time_s": t})
    return out


def main():
    res = run()
    for key, rows in res.items():
        for r in rows:
            vals = ",".join(f"{k}={v:.3f}" if isinstance(v, float)
                            else f"{k}={v}" for k, v in r.items())
            print(f"param_{key}: {vals}")


if __name__ == "__main__":
    main()
