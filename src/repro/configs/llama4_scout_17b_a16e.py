"""llama4-scout-17b-a16e [moe] — MoE top-1 + shared expert, early fusion.

Assigned: 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 16e top-1.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Every layer is MoE (16 routed experts, top-1) plus one always-on shared
expert of the same width (Scout's A16E layout).  Early-fusion multimodality
is out of the assigned backbone scope (text path only)."""
from repro.models import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=8192, vocab_size=202048,
    head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192, num_shared=1,
                  first_dense_layers=0, router_renorm=False))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=32, num_shared=1,
                      router_renorm=False),
        dtype="float32", remat="none")
