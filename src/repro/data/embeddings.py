"""Embedding providers for the KOIOS similarity function.

* :class:`EmbeddingTableProvider` — frozen table (the paper's FastText
  role), built from ``make_embeddings`` or loaded from a checkpoint.
* ``tower_embeddings`` — pull the token-embedding matrix out of any trained
  model tower of the framework (``repro.models``): the embedding table of a
  trained LM *is* a semantic similarity provider, which is how the KOIOS
  serving path composes with the assigned architectures.
"""
from __future__ import annotations

import numpy as np

from ..core.similarity import EmbeddingSimilarity


class EmbeddingTableProvider(EmbeddingSimilarity):
    """Frozen (vocab, dim) table provider with coverage accounting.

    ``coverage`` mimics the paper's pre-trained-vector coverage filter
    (sets with <70% coverage are discarded upstream); uncovered tokens get
    a random unique direction — they only ever match identically (the
    out-of-vocabulary rule of paper §V).
    """

    def __init__(self, table: np.ndarray, coverage: float = 1.0,
                 seed: int = 0):
        table = np.asarray(table, np.float32)
        if coverage < 1.0:
            rng = np.random.default_rng(seed + 3)
            n = len(table)
            uncovered = rng.random(n) > coverage
            rand = rng.normal(size=(int(uncovered.sum()), table.shape[1]))
            rand /= np.linalg.norm(rand, axis=1, keepdims=True)
            table = table.copy()
            table[uncovered] = rand.astype(np.float32)
        super().__init__(table)


def tower_embeddings(params: dict) -> np.ndarray:
    """Extract a model tower's token-embedding table as a similarity table.

    Works with any ``repro.models`` parameter pytree (the embedding lives at
    ``params['embed']['table']``).
    """
    table = np.asarray(params["embed"]["table"], np.float32)
    norms = np.linalg.norm(table, axis=1, keepdims=True)
    return table / np.maximum(norms, 1e-6)
