"""CSR inverted index I_s: vocabulary token -> sets containing it.

The paper stores I_s as an in-memory hash map of posting lists.  The TPU
adaptation is a CSR matrix over the token axis so a whole stream chunk's
postings are fetched with one vectorized gather (DESIGN.md §2).

``posting_set``  : set id of each posting
``posting_slot`` : index of the posting *within the repository's flat token
                   array* — this is the per-(set, element) slot used by the
                   refinement phase to mark candidate-side elements as
                   matched (the t-side occupancy of the greedy matching).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .types import SetCollection


@dataclasses.dataclass(frozen=True)
class InvertedIndex:
    tok_indptr: np.ndarray    # (vocab+1,) int64
    posting_set: np.ndarray   # (total_postings,) int32
    posting_slot: np.ndarray  # (total_postings,) int64  (flat token-array slot)
    vocab_size: int

    @property
    def total_postings(self) -> int:
        return len(self.posting_set)

    def postings(self, token: int):
        lo, hi = self.tok_indptr[token], self.tok_indptr[token + 1]
        return self.posting_set[lo:hi], self.posting_slot[lo:hi]

    def posting_counts(self) -> np.ndarray:
        return np.diff(self.tok_indptr)

    @staticmethod
    def build(coll: SetCollection) -> "InvertedIndex":
        """O(total_tokens) counting-sort construction."""
        tokens = coll.set_tokens.astype(np.int64)
        order = np.argsort(tokens, kind="stable")
        sorted_tokens = tokens[order]
        counts = np.bincount(sorted_tokens, minlength=coll.vocab_size)
        tok_indptr = np.zeros(coll.vocab_size + 1, dtype=np.int64)
        np.cumsum(counts, out=tok_indptr[1:])
        # set id of every flat slot
        set_of_slot = np.repeat(
            np.arange(coll.num_sets, dtype=np.int32), coll.set_sizes)
        return InvertedIndex(
            tok_indptr=tok_indptr,
            posting_set=set_of_slot[order],
            posting_slot=order,
            vocab_size=coll.vocab_size,
        )

    def memory_bytes(self) -> int:
        return (self.tok_indptr.nbytes + self.posting_set.nbytes
                + self.posting_slot.nbytes)
