"""Vectorized KOIOS bounds & filters (paper §III & §V, DESIGN.md §2/§8.5).

All filter state is dense per-set arrays; every bound update is a masked
vector pass over the live sets (replacing the paper's event-driven bucket
structure — see DESIGN.md §2 for why that is the TPU-correct shape).

Bounds implemented:
  * LB / iLB  — incremental greedy partial-matching score S (Lemma 5);
  * UB (arrival)  — min(|Q|,|C|) * firstsim   (Lemma 2);
  * iUB paper mode — S + min(|Q|-l, |C|-l) * s_now  (the paper's Lemma 6;
    UNSOUND, kept only for reproducing the paper's pruning-power numbers);
  * iUB sound mode — T + max(0, cap - d) * s_now  where T is the sum of the
    first-seen similarity of each distinct query element streamed with C and
    d their count (DESIGN.md §8.5 — provably >= SO);
  * theta_lb — k-th largest LB over candidate sets (Lemma 4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k",))
def kth_largest(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """k-th largest entry of x (theta computations).  k is static."""
    k = min(k, x.shape[0])
    vals = jax.lax.top_k(x, k)[0]
    return vals[k - 1]


def compute_iub(S, l, T, d, cap, s_now, seen, mode: str):
    """Current upper bound per set; +inf-ish for unseen sets (never pruned
    here — an unseen set's bound is applied on arrival)."""
    capf = cap.astype(jnp.float32)
    if mode == "paper":
        m = jnp.maximum(capf - l.astype(jnp.float32), 0.0)
        ub = S + m * s_now
    else:
        rem = jnp.maximum(capf - d.astype(jnp.float32), 0.0)
        ub = T + rem * s_now
    return jnp.where(seen, ub, jnp.float32(3.4e38))


def prune_mask(iub, theta_lb, seen, alive):
    """Sets killed by the UB filter this round (strict <: ties survive)."""
    return alive & seen & (iub < theta_lb)
