"""Deterministic synthetic LM token pipeline (sharded, prefetchable).

Every batch is a pure function of (seed, step, shard) — exactly
reproducible across restarts and elastic re-sharding: after a preemption the
restored step counter regenerates the identical stream, and re-sharding to
a different data-parallel degree re-partitions the same global batch
(fault-tolerance property tested in tests/test_train_substrate.py).

The token stream is a Zipfian-unigram + Markov-bigram mixture so the loss
is learnable (not pure noise) at smoke scale."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = ranks ** (-cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        # sparse deterministic bigram: each token prefers a few successors
        self.succ = rng.integers(0, v, size=(v, 4))

    def global_batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.choice(v, size=B, p=self.unigram)
        follow = rng.random((B, S)) < 0.7
        fresh = rng.choice(v, size=(B, S), p=self.unigram)
        pick = rng.integers(0, self.succ.shape[1], size=(B, S))
        for t in range(1, S):
            nxt = self.succ[toks[:, t - 1], pick[:, t]]
            toks[:, t] = np.where(follow[:, t], nxt, fresh[:, t])
        return {"tokens": toks, "labels": toks.copy()}

    def shard_batch(self, step: int, shard: int, num_shards: int) -> dict:
        """The shard's slice of the deterministic global batch."""
        g = self.global_batch(step)
        B = self.cfg.global_batch
        assert B % num_shards == 0
        lo = (B // num_shards) * shard
        hi = lo + B // num_shards
        return {k: v[lo:hi] for k, v in g.items()}
