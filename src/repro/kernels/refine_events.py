"""Pallas kernel: set-segmented greedy admission of one refinement chunk.

The refinement scan's admission loop is the filter phase's inner hot
path (DESIGN.md §2): per event it reads/writes a handful of per-set
state entries (S, l, T, d, seen, qmatched, qseen, slot_matched).  The
jnp serial path round-trips every one of those scalar scatters through
XLA ops over HBM-resident arrays; this kernel keeps the ENTIRE carry in
VMEM for the whole chunk and walks the chunk's lane-packed
set-segmented layout (``token_stream.pack_events_segmented``): rows are
rank *levels* — at most one event per set — so row-major admission
order is bit-identical to the serial per-event loop (cross-set events
commute), while the sequential dependency chain shrinks from one step
per event to one per level.

State gathers/scatters are dynamic scalar ``pl.load``/``pl.store``
pairs guarded by ``pl.when`` — the same pattern as
``refine_verify._compact_kernel`` (dynamic scalar stores lower on
Mosaic where a vector scatter would not).  VMEM budget: the carry is
O(num_sets * q_words + total_slots) int32/uint32 lanes — a few hundred
KB at repository-partition sizes, far under the ~16 MB VMEM budget.

The pure-jnp oracle is ``ref.refine_events_packed_ref`` — the SAME
function the production segmented layout runs — and ``ops.
refine_events`` dispatches with interpret mode off-TPU
(tests/test_kernels.py asserts bit-parity).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scal(ref, *idx):
    """Scalar load from a 2-D ref at dynamic indices."""
    return pl.load(ref, tuple(pl.dslice(i, 1) for i in idx))[0, 0]


def _store(ref, val, *idx):
    pl.store(ref, tuple(pl.dslice(i, 1) for i in idx),
             val.reshape(1, 1))


def _refine_events_kernel(set_ref, q_ref, slot_ref, sim_ref, alive_ref,
                          s_in, l_in, t_in, d_in, seen_in, qm_in, qs_in,
                          sm_in,
                          s_out, l_out, t_out, d_out, seen_out, qm_out,
                          qs_out, sm_out, *, W: int, L: int):
    # carry copies through; the level loop then accumulates in the
    # output refs (VMEM-resident for the whole chunk)
    s_out[...] = s_in[...]
    l_out[...] = l_in[...]
    t_out[...] = t_in[...]
    d_out[...] = d_in[...]
    seen_out[...] = seen_in[...]
    qm_out[...] = qm_in[...]
    qs_out[...] = qs_in[...]
    sm_out[...] = sm_in[...]

    def lane(j, t):
        C = _scal(set_ref, t, j)

        @pl.when(C >= 0)
        def _():
            Ci = jnp.maximum(C, 0)
            do = _scal(alive_ref, 0, Ci) > 0

            @pl.when(do)
            def _():
                q = _scal(q_ref, t, j)
                slot = _scal(slot_ref, t, j)
                s = _scal(sim_ref, t, j)
                qw = q >> 5
                bit = jnp.uint32(1) << (q & 31).astype(jnp.uint32)

                # --- first-seen bookkeeping (sound iUB') ---------------
                qs_word = _scal(qs_out, Ci, qw)
                first = (qs_word & bit) == 0

                @pl.when(first)
                def _():
                    _store(t_out, _scal(t_out, 0, Ci) + s, 0, Ci)
                    _store(d_out, _scal(d_out, 0, Ci) + 1, 0, Ci)
                    _store(qs_out, qs_word | bit, Ci, qw)

                _store(seen_out, jnp.int32(1), 0, Ci)

                # --- greedy admission (iLB, Lemma 5) -------------------
                qm_word = _scal(qm_out, Ci, qw)
                adm = ((qm_word & bit) == 0) \
                    & (_scal(sm_out, 0, slot) == 0)

                @pl.when(adm)
                def _():
                    _store(s_out, _scal(s_out, 0, Ci) + s, 0, Ci)
                    _store(l_out, _scal(l_out, 0, Ci) + 1, 0, Ci)
                    _store(qm_out, qm_word | bit, Ci, qw)
                    _store(sm_out, jnp.int32(1), 0, slot)

        return t

    def level(t, _):
        jax.lax.fori_loop(0, L, lane, t)
        return 0

    jax.lax.fori_loop(0, W, level, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def refine_events(state, c_set, c_q, c_slot, c_sim,
                  interpret: bool = False):
    """Admit one lane-packed (W, L) chunk into the refinement carry.

    ``state`` is (S, l, T, d, seen, alive, qmatched, qseen,
    slot_matched) — the per-set carry minus theta, ``alive`` read-only.
    Returns the mutated fields (S, l, T, d, seen, qmatched, qseen,
    slot_matched), bit-identical to ``ref.refine_events_packed_ref``.
    """
    S, l, T, d, seen, alive, qmatched, qseen, slot_matched = state
    W, L = c_set.shape
    n = S.shape[0]
    n_slots = slot_matched.shape[0]
    q_words = qmatched.shape[1]

    def spec(*shape):
        return pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))

    outs = pl.pallas_call(
        functools.partial(_refine_events_kernel, W=W, L=L),
        in_specs=[spec(W, L)] * 4 + [
            spec(1, n),                       # alive
            spec(1, n), spec(1, n), spec(1, n), spec(1, n),   # S l T d
            spec(1, n),                       # seen
            spec(n, q_words), spec(n, q_words),
            spec(1, n_slots),
        ],
        out_specs=[
            spec(1, n), spec(1, n), spec(1, n), spec(1, n),
            spec(1, n),
            spec(n, q_words), spec(n, q_words),
            spec(1, n_slots),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((n, q_words), jnp.uint32),
            jax.ShapeDtypeStruct((n, q_words), jnp.uint32),
            jax.ShapeDtypeStruct((1, n_slots), jnp.int32),
        ],
        interpret=interpret,
    )(c_set, c_q, c_slot.astype(jnp.int32), c_sim,
      alive.astype(jnp.int32)[None, :],
      S[None, :], l[None, :], T[None, :], d[None, :],
      seen.astype(jnp.int32)[None, :], qmatched, qseen,
      slot_matched.astype(jnp.int32)[None, :])
    (S2, l2, T2, d2, seen2, qm2, qs2, sm2) = outs
    return (S2[0], l2[0], T2[0], d2[0], seen2[0] > 0, qm2, qs2,
            sm2[0] > 0)
