"""Encoder-decoder transformer (seamless-m4t family).

The modality frontend is a STUB per the assignment: ``input_specs``
provides precomputed audio frame embeddings (B, enc_len, d) as the encoder
input; the text decoder is a standard causal transformer with cross
attention.  Decode caches: self-attn KV (growing) + cross-attn KV
(computed once from the encoder memory at prefill)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention, attention_init, blocked_xent, dense,
                     dtype_of, embed, embed_init, rmsnorm, rmsnorm_init,
                     softmax_xent, swiglu, swiglu_init, unembed)


def _enc_layer_init(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {"attn_norm": rmsnorm_init(cfg.d_model, dtype),
            "attn": attention_init(ka, cfg, dtype),
            "mlp_norm": rmsnorm_init(cfg.d_model, dtype),
            "mlp": swiglu_init(km, cfg.d_model, cfg.d_ff, dtype)}


def _dec_layer_init(key, cfg, dtype):
    ka, kc, km = jax.random.split(key, 3)
    return {"self_norm": rmsnorm_init(cfg.d_model, dtype),
            "self_attn": attention_init(ka, cfg, dtype),
            "cross_norm": rmsnorm_init(cfg.d_model, dtype),
            "cross_attn": attention_init(kc, cfg, dtype),
            "mlp_norm": rmsnorm_init(cfg.d_model, dtype),
            "mlp": swiglu_init(km, cfg.d_model, cfg.d_ff, dtype)}


def _stack(key, n, mk, cfg, dtype):
    keys = jax.random.split(key, n)
    layers = [mk(k, cfg, dtype) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = dtype_of(cfg.dtype)

    def init(self, key):
        cfg = self.cfg
        k0, k1, k2, k3 = jax.random.split(key, 4)
        return {
            "embed": embed_init(k0, cfg.vocab_size, cfg.d_model, self.dtype),
            "encoder": _stack(k1, cfg.enc_layers, _enc_layer_init, cfg,
                              self.dtype),
            "decoder": _stack(k2, cfg.num_layers, _dec_layer_init, cfg,
                              self.dtype),
            "enc_norm": rmsnorm_init(cfg.d_model, self.dtype),
            "final_norm": rmsnorm_init(cfg.d_model, self.dtype),
        }

    def param_specs(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    # -------------------------------------------------------------- encode
    def encode(self, params, frames):
        """frames: (B, enc_len, d) stub embeddings -> encoder memory."""
        cfg = self.cfg
        B, S, _ = frames.shape
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        full = jnp.ones((1, 1, S, S), bool)          # bidirectional

        def body(h, layer_p):
            a, _ = attention(layer_p["attn"], cfg,
                             rmsnorm(layer_p["attn_norm"], h), positions,
                             mask=full)
            h = h + a
            h = h + swiglu(layer_p["mlp"], rmsnorm(layer_p["mlp_norm"], h))
            return h, None

        fn = jax.checkpoint(body) if cfg.remat != "none" else body
        h, _ = jax.lax.scan(fn, frames.astype(self.dtype),
                            params["encoder"], unroll=cfg.scan_unroll)
        return rmsnorm(params["enc_norm"], h)

    # -------------------------------------------------------------- decode
    def _dec_layer(self, p, x, positions, memory, self_cache=None,
                   cache_index=None, cross_kv=None):
        cfg = self.cfg
        a, new_self = attention(p["self_attn"], cfg,
                                rmsnorm(p["self_norm"], x), positions,
                                cache=self_cache, cache_index=cache_index)
        x = x + a
        h = rmsnorm(p["cross_norm"], x)
        if cross_kv is not None:
            # decode: use precomputed cross K/V (MQA-style gather-free)
            c, _ = attention(p["cross_attn"], cfg, h, positions,
                             cache=None, x_kv=memory)
        else:
            c, _ = attention(p["cross_attn"], cfg, h, positions,
                             x_kv=memory)
        x = x + c
        x = x + swiglu(p["mlp"], rmsnorm(p["mlp_norm"], x))
        return x, new_self

    def _decoder(self, params, tokens, memory, positions):
        cfg = self.cfg
        x = embed(params["embed"], tokens)

        def body(h, layer_p):
            h, self_cache = self._dec_layer(layer_p, h, positions, memory)
            return h, self_cache

        fn = jax.checkpoint(body) if cfg.remat != "none" else body
        x, caches = jax.lax.scan(fn, x, params["decoder"],
                                 unroll=cfg.scan_unroll)
        return rmsnorm(params["final_norm"], x), caches

    def loss(self, params, batch):
        """batch: frames (B,F,d), tokens (B,S), labels (B,S)."""
        memory = self.encode(params, batch["frames"])
        B, S = batch["tokens"].shape
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        x, _ = self._decoder(params, batch["tokens"], memory, positions)
        if self.cfg.xent_block:
            return blocked_xent(x[:, :-1], params["embed"]["table"],
                                batch["labels"][:, 1:], self.cfg.xent_block)
        logits = unembed(params["embed"], x)
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:])

    # ------------------------------------------------------------- serving
    def cache_specs(self, batch: int, max_seq: int, enc_len: int = 0):
        cfg = self.cfg
        L = cfg.num_layers
        enc_len = enc_len or cfg.frontend_len
        kv = (L, batch, max_seq, cfg.num_kv_heads, cfg.hd)
        return {"k": jax.ShapeDtypeStruct(kv, self.dtype),
                "v": jax.ShapeDtypeStruct(kv, self.dtype),
                "memory": jax.ShapeDtypeStruct(
                    (batch, enc_len, cfg.d_model), self.dtype)}

    def init_cache(self, batch: int, max_seq: int, enc_len: int = 0):
        return jax.tree_util.tree_map(
            lambda sp: jnp.zeros(sp.shape, sp.dtype),
            self.cache_specs(batch, max_seq, enc_len))

    def prefill(self, params, batch, max_seq=None):
        memory = self.encode(params, batch["frames"])
        B, S = batch["tokens"].shape
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        x, caches = self._decoder(params, batch["tokens"], memory, positions)
        if max_seq is not None and max_seq > S:
            caches = jax.tree_util.tree_map(
                lambda c: jnp.pad(
                    c, [(0, 0), (0, 0), (0, max_seq - S), (0, 0), (0, 0)]),
                caches)
        logits = unembed(params["embed"], x[:, -1:])
        return logits, {"k": caches["k"], "v": caches["v"],
                        "memory": memory}

    def decode_step(self, params, caches, token, cache_index):
        cfg = self.cfg
        x = embed(params["embed"], token)
        B = x.shape[0]
        positions = jnp.full((B, 1), cache_index, jnp.int32)
        memory = caches["memory"]

        def body(h, xs):
            layer_p, self_cache = xs
            h, new_self = self._dec_layer(
                layer_p, h, positions, memory, self_cache=self_cache,
                cache_index=cache_index, cross_kv=True)
            return h, new_self

        x, new_kv = jax.lax.scan(
            body, x, (params["decoder"], {"k": caches["k"],
                                          "v": caches["v"]}),
            unroll=self.cfg.scan_unroll)
        x = rmsnorm(params["final_norm"], x)
        logits = unembed(params["embed"], x)
        return logits, {"k": new_kv["k"], "v": new_kv["v"],
                        "memory": memory}
