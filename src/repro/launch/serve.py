"""Serving driver: a thin shell over the continuous-batching request
engine (``repro.runtime.engine``, DESIGN.md §3.2).

Every request is admitted into the engine's queue (optional deadlines),
coalesced into the next partition wave with whatever else has arrived
(mid-flight joins are sound — row numerics are schedule-invariant),
served through the LRU token-stream cache and pow2 shape buckets, and
responded to with its TRUE admit->respond latency — the historical
``serve_batch`` reported one amortized number for every query in the
batch.  ``--fused`` drives each wave's partition groups as fused
on-device programs (DESIGN.md §3.1); ``--mesh-bounds`` runs the theta_lb
exchange as a real all-reduce-max over the repository mesh (DESIGN.md
§5).  ``--per-query`` keeps the per-query one-shot loop as the A/B
baseline (bit-identical results).  ``--deadline-ms``/``--shed`` exercise
the fault-tolerant serving plane (DESIGN.md §6): per-request deadlines
with deadline-aware shedding, and the summary reports p50/p99 latency,
deadline-met ratio, and shed/retry/failed accounting.

Crash consistency (DESIGN.md §6.5): ``--snapshot-dir`` restores the
collection from the latest epoch manifest on startup (falling back to a
fresh build, snapshotted immediately) and re-snapshots on every live-
update commit; ``--update-after N`` applies a deterministic live update
(remove set 0, add two copied sets) once N requests have been served;
``--kill-after-update`` exits with code 17 right after the commit+
snapshot (the CI restart-recovery job's crash point); ``--skip N``
resumes the request trace at global request N after a restart.  The
``served_hash`` printed at the end is the restart-parity check: a run
killed after the update and a restored run serving the remaining trace
hash to exactly the uninterrupted run's pre/post-update hashes.

Smoke scale:
    PYTHONPATH=src python -m repro.launch.serve --requests 4 --k 5
"""
from __future__ import annotations

import argparse
import hashlib
import time

import numpy as np

from ..core import (EmbeddingSimilarity, KoiosSearch, SearchParams)
from ..data import (EmbeddingTableProvider, dataset_preset, make_embeddings,
                    sample_queries)
from ..runtime.engine import RequestEngine


def _response_dict(r) -> dict:
    """One EngineResponse -> the serving-API response payload."""
    return {
        "ids": r.result.ids.tolist(),
        "scores": r.result.lb.tolist(),
        "status": r.status,                     # ok | shed | retried | failed
        "retries": r.retries,
        "reason": r.reason,
        "latency_s": round(r.latency_s, 4),     # true per-request
        "queue_s": round(r.queue_s, 4),
        "waves": r.waves,
        "stream_cache_hit": r.stream_hit,
        "deadline_met": r.deadline_met,
        "stats": r.result.stats.as_dict(),
    }


def _served_hash(results) -> str:
    """Order-sensitive digest of the SERVED responses (ids + scores) —
    the restart-recovery parity check: equal hashes mean bit-identical
    served results, whatever process lifetimes produced them."""
    h = hashlib.sha256()
    for r in results:
        if r.get("status", "ok") in ("ok", "retried"):
            h.update(np.asarray(r["ids"], np.int64).tobytes())
            h.update(np.asarray(r["scores"], np.float64).tobytes())
    return h.hexdigest()[:16]


def _demo_update(collection, base_coll) -> int:
    """The deterministic live update of ``--update-after``: remove set 0,
    add copies of base sets 1 and 2.  Pure function of the BASE corpus,
    so an interrupted run and its restored successor commit the same
    epoch-1 repository bit-for-bit."""
    u = collection.begin_update()
    u.remove_sets([0])
    u.add_sets([base_coll.get_set(1).copy(), base_coll.get_set(2).copy()])
    return u.commit()


class SearchServer:
    """Request-engine serving with a one-shot per-query baseline.

    ``serve_batch`` admits the batch into the :class:`RequestEngine`
    and drains it: every response carries its own admit->respond
    latency, queue time, wave count, and stream-cache attribution.
    ``batched=False`` falls back to the per-query one-shot loop
    (identical results — the A/B baseline of
    ``benchmarks/response_time.py``).

    The repository lives in ONE :class:`ShardedCollection` resource
    (built here, optionally placed across ``shards`` devices) shared by
    the one-shot baseline and every engine replica — one front door over
    one logical collection (DESIGN.md §5).  ``replicas > 1`` serves
    through an :class:`~repro.runtime.engine.AdmissionRouter` fleet."""

    def __init__(self, coll, sim, params: SearchParams, partitions: int,
                 schedule: str = "overlap", bound_exchange=None, mesh=None,
                 stream_cache_bytes: int = 64 << 20, replicas: int = 1,
                 shards: int = 0, place: bool = False,
                 shed_deadlines: bool = False, fault_plan=None,
                 collection=None):
        from ..runtime.collection import ShardedCollection
        from ..runtime.engine import AdmissionRouter

        # collection= injects a pre-existing resource — the restart path
        # restores one from a --snapshot-dir manifest instead of building
        if collection is None:
            collection = ShardedCollection.build(
                coll, shards or partitions,
                devices="auto" if place else None)
        self.collection = collection
        self.one_shot = KoiosSearch(None, sim, params,
                                    schedule=schedule,
                                    bound_exchange=bound_exchange,
                                    mesh=mesh, collection=self.collection)
        engine_kwargs = dict(
            schedule="fused" if schedule == "fused" else "wave",
            bound_exchange=bound_exchange, mesh=mesh,
            stream_cache_bytes=stream_cache_bytes,
            shed_deadlines=shed_deadlines)
        if fault_plan is not None and replicas > 1:
            engine_kwargs["fault_plan"] = fault_plan
        if replicas > 1:
            self.engine = AdmissionRouter(
                None, sim, params, replicas=replicas,
                collection=self.collection, **engine_kwargs)
        else:
            self.engine = RequestEngine(
                None, sim, params, collection=self.collection,
                **engine_kwargs)

    def serve_batch(self, queries, batched: bool = True, deadlines=None):
        """One request batch -> list of response dicts (request order)."""
        queries = [np.asarray(q, np.int32) for q in queries]
        if batched:
            responses = self.engine.serve(queries, deadlines=deadlines)
            return [_response_dict(r) for r in responses]
        out = []
        for q in queries:
            t0 = time.monotonic()
            res = self.one_shot.search(q)
            out.append({
                "ids": res.ids.tolist(),
                "scores": res.lb.tolist(),
                "latency_s": round(time.monotonic() - t0, 4),
                "stats": res.stats.as_dict(),
            })
        return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="opendata")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=0.8)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--shards", type=int, default=0,
                    help="shard count of the collection resource "
                         "(defaults to --partitions; the shards ARE the "
                         "scheduler's partitions)")
    ap.add_argument("--place", action="store_true",
                    help="pin shard i's device arrays to device i "
                         "(round-robin over jax.devices()); waves run "
                         "where their shard lives and the theta_lb "
                         "carry hops between shard devices")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas over the ONE shared collection "
                         "resource, behind the admission router "
                         "(load-routed, globally ordered responses)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--stagger-ms", type=float, default=0.0,
                    help="replay the request trace with this inter-arrival "
                         "gap instead of submitting each batch at once "
                         "(continuous batching joins mid-flight)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline (admit + this many ms); "
                         "reported met/missed per response, and with "
                         "--shed doomed requests are dropped before "
                         "occupying a wave tile (status=shed)")
    ap.add_argument("--shed", action="store_true",
                    help="deadline-aware shedding (DESIGN.md §6): requests "
                         "whose deadline is already unreachable respond "
                         "status=shed instead of burning wave tiles")
    ap.add_argument("--per-query", action="store_true",
                    help="serve each query independently through the "
                         "one-shot path (A/B baseline for the engine)")
    sched = ap.add_mutually_exclusive_group()
    sched.add_argument("--sequential", action="store_true",
                       help="one-shot baseline schedule for --per-query; "
                            "the engine's host waves are unaffected "
                            "(bit-identical results either way)")
    sched.add_argument("--fused", action="store_true",
                       help="serve with fused on-device wave programs "
                            "(DESIGN.md §3) — interpret mode off-TPU; "
                            "bit-identical results")
    ap.add_argument("--mesh-bounds", action="store_true",
                    help="run the theta_lb exchange as an all-reduce-max "
                         "over a device mesh (DESIGN.md §5)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="crash consistency (DESIGN.md §6.5): restore the "
                         "collection from this directory's epoch manifest "
                         "on startup (build fresh + snapshot when none "
                         "exists) and re-snapshot on every live-update "
                         "commit")
    ap.add_argument("--update-after", type=int, default=0,
                    help="apply the deterministic demo live update "
                         "(remove set 0, add two copied sets) once this "
                         "many requests have been served; 0 = never")
    ap.add_argument("--kill-after-update", action="store_true",
                    help="exit with code 17 immediately after the "
                         "--update-after commit (and its snapshot) — the "
                         "restart-recovery smoke's crash point")
    ap.add_argument("--skip", type=int, default=0,
                    help="skip the first N requests of the trace, keeping "
                         "global request numbering (restart resume)")
    args = ap.parse_args(argv)

    bound_exchange = None
    mesh = None
    if args.mesh_bounds:
        from ..runtime.sharding import bound_exchange_for
        from .mesh import bound_exchange_mesh
        mesh = bound_exchange_mesh()
        bound_exchange = bound_exchange_for(mesh)

    print(f"[serve] building corpus ({args.dataset} @ {args.scale})")
    coll = dataset_preset(args.dataset, scale=args.scale, seed=0)
    emb = make_embeddings(coll.vocab_size, dim=args.dim, seed=0)
    sim = EmbeddingTableProvider(emb)
    import jax
    fused_mode = "auto" if jax.default_backend() == "tpu" else (
        "interpret" if args.fused else "auto")
    params = SearchParams(k=args.k, alpha=args.alpha, fused=fused_mode)
    schedule = ("sequential" if args.sequential
                else "fused" if args.fused else "overlap")
    collection = None
    if args.snapshot_dir:
        from ..runtime.collection import ShardedCollection
        collection = ShardedCollection.restore(
            args.snapshot_dir, devices="auto" if args.place else None)
        if collection is not None:
            print(f"[serve] restored collection epoch "
                  f"{collection.epoch} from {args.snapshot_dir}")
    server = SearchServer(coll, sim, params, args.partitions,
                          schedule=schedule,
                          bound_exchange=bound_exchange, mesh=mesh,
                          replicas=args.replicas, shards=args.shards,
                          place=args.place, shed_deadlines=args.shed,
                          collection=collection)
    if args.snapshot_dir:
        if collection is None:
            # nothing to restore: persist the initial epoch NOW, so a
            # crash before the first commit still restores epoch 0
            server.collection.save(args.snapshot_dir)
        server.collection.on_commit(
            lambda sc: sc.save(args.snapshot_dir))
    desc = server.collection.describe()
    placed = [s["device"] for s in desc["shards"] if s["device"]]
    print(f"[serve] corpus: {coll.num_sets} sets, vocab {coll.vocab_size}, "
          f"{server.collection.num_shards} shards"
          + (f" on {len(set(placed))} devices" if placed else "")
          + (f", {args.replicas} replicas" if args.replicas > 1 else ""))

    # queries ALWAYS sample from the pristine built corpus — never the
    # restored collection — so an interrupted run and its restored
    # successor replay the identical request trace (restart parity)
    queries = sample_queries(coll, args.requests, seed=1)
    dl = args.deadline_ms / 1e3 if args.deadline_ms else None
    served_pre: list = []           # responses before the live update
    served_post: list = []          # responses at/after it
    updated = server.collection.epoch > 0      # restored past the update
    for lo in range(args.skip, len(queries), args.batch_size):
        batch = queries[lo:lo + args.batch_size]
        if args.stagger_ms and not args.per_query:
            now = server.engine.clock()
            for i, q in enumerate(batch):
                t_arr = now + i * args.stagger_ms / 1e3
                server.engine.submit(
                    q, arrival=t_arr,
                    deadline=t_arr + dl if dl else None)
            results = [_response_dict(r)
                       for r in sorted(server.engine.drain(),
                                       key=lambda r: r.rid)]
        else:
            now = server.engine.clock()
            results = server.serve_batch(
                batch, batched=not args.per_query,
                deadlines=[now + dl] * len(batch) if dl else None)
        (served_post if updated else served_pre).extend(results)
        for i, r in enumerate(results):
            if not args.per_query and r["status"] in ("shed", "failed"):
                print(f"req {lo+i}: {r['status']} ({r['reason']}) "
                      f"lat={r['latency_s']}s waves={r['waves']}")
                continue
            extra = ("" if args.per_query else
                     f"status={r['status']} queue={r['queue_s']}s "
                     f"waves={r['waves']} "
                     f"cached={r['stream_cache_hit']} ")
            print(f"req {lo+i}: top-{args.k} ids={r['ids'][:5]}... "
                  f"scores={[round(s,2) for s in r['scores'][:5]]} "
                  f"lat={r['latency_s']}s {extra}"
                  f"verified={r['stats']['exact_matches']}")
        if (args.update_after and not updated
                and lo + len(batch) - args.skip >= args.update_after):
            epoch = _demo_update(server.collection, coll)
            updated = True
            print(f"[serve] live update committed: epoch {epoch} "
                  f"({server.collection.coll.num_sets} sets)"
                  + (f", snapshotted to {args.snapshot_dir}"
                     if args.snapshot_dir else ""))
            if args.kill_after_update:
                print(f"[serve] served_hash={_served_hash(served_pre)} "
                      f"requests={len(served_pre)} epoch=0")
                print("[serve] killed after update (exit 17)")
                return 17
    if not args.per_query:
        if served_pre:
            print(f"[serve] pre_update_hash={_served_hash(served_pre)} "
                  f"requests={len(served_pre)}")
        if served_post:
            print(f"[serve] post_update_hash={_served_hash(served_post)} "
                  f"requests={len(served_post)}")
        print(f"[serve] served_hash="
              f"{_served_hash(served_pre + served_post)} "
              f"requests={len(served_pre) + len(served_post)} "
              f"epoch={server.collection.epoch}")
    if not args.per_query:
        s = server.engine.summary()
        replicas = s.get("per_replica", [s])
        if "per_replica" in s:
            print(f"  [router] replicas={s['replicas']} "
                  f"(healthy={s['healthy_replicas']}) "
                  f"requests={s['requests']} waves={s['waves']} "
                  f"shed={s['shed']} retries={s['retries']} "
                  f"failed={s['failed']} "
                  f"quarantines={s['quarantines']} "
                  f"p50={s['p50_latency_s']:.4f}s "
                  f"p99={s['p99_latency_s']:.4f}s "
                  f"device_bytes={s['collection']['device_bytes']}")
        for ri, p in enumerate(replicas):
            cache = p["stream_cache"]
            tag = f"replica {ri}" if "per_replica" in s else "engine"
            print(f"  [{tag}] schedule={p['schedule']} "
                  f"requests={p['requests']} served={p['served']} "
                  f"shed={p['shed']} steps={p['steps']} "
                  f"mean_lat={p['mean_latency_s']:.4f}s "
                  f"p50={p['p50_latency_s']:.4f}s "
                  f"p95={p['p95_latency_s']:.4f}s "
                  f"p99={p['p99_latency_s']:.4f}s "
                  f"deadline_met={p['deadline_met_ratio']:.2f} "
                  f"mean_queue_depth={p['mean_queue_depth']:.1f} "
                  f"waves={p['scheduler']['waves']} "
                  f"cache_hit_rate={cache['hit_rate']:.2f} "
                  f"(hits={cache['hits']} misses={cache['misses']} "
                  f"evictions={cache['evictions']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
