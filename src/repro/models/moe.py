"""Mixture-of-Experts FFN — dropless sorted ragged_dot dispatch.

Routing: softmax router, top-k experts per token, optional weight
renormalization (DeepSeek-style) + optional shared (always-on) experts.

Dispatch: token-expert pairs are sorted by expert id and the three expert
matmuls run as ``jax.lax.ragged_dot`` grouped GEMMs (MXU-native, no (T,E,C)
dispatch tensors — this is what scales to 256 experts).  Under GSPMD the
expert (group) dimension is sharded over the EP axis; the sort/gather
becomes an all-to-all.  See runtime/sharding.py for the EP rules and
DESIGN.md §5.

Aux losses: load-balance (Switch-style) recorded for the training loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init
from .config import MoEConfig


def moe_init(key, d_model: int, mcfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    E, dff = mcfg.num_experts, mcfg.d_ff_expert
    scale = d_model ** -0.5

    def stack(k, d_in, d_out):
        w = jax.random.normal(k, (E, d_in, d_out), jnp.float32) * scale
        return w.astype(dtype)

    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "w_gate": stack(ks[1], d_model, dff),
        "w_up": stack(ks[2], d_model, dff),
        "w_down": stack(ks[3], dff, d_model),
    }
    if mcfg.num_shared:
        from .layers import swiglu_init
        p["shared"] = swiglu_init(ks[4], d_model,
                                  dff * mcfg.num_shared, dtype)
    return p


def _route(params, xf, mcfg: MoEConfig):
    E, K = mcfg.num_experts, mcfg.top_k
    logits = (xf.astype(jnp.float32) @ params["router"]["w"])    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                          # (T, K)
    if mcfg.router_renorm:
        topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, E, dtype=jnp.float32), axis=1), axis=0)
    aux = {"lb_loss": E * jnp.sum(me * ce) / K}
    return topw, topi, aux


def moe_ffn(params, x, mcfg: MoEConfig):
    """x: (..., d) -> (..., d), plus aux dict.  Dispatch per mcfg.impl."""
    if mcfg.impl == "dispatch":
        return moe_ffn_dispatch(params, x, mcfg)
    if mcfg.impl == "gather":
        return moe_ffn_gather(params, x, mcfg)
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    E, K = mcfg.num_experts, mcfg.top_k
    topw, topi, aux = _route(params, xf, mcfg)

    flat_e = topi.reshape(-1)                                     # (T*K,)
    order = jnp.argsort(flat_e)
    token_of = order // K                                          # source token
    xs = jnp.take(xf, token_of, axis=0)                           # (T*K, d)
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, params["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(xs, params["w_up"], group_sizes)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(x.dtype)
    y_sorted = jax.lax.ragged_dot(h, params["w_down"], group_sizes)

    # unsort + combine with routing weights
    w_sorted = jnp.take(topw.reshape(-1), order).astype(jnp.float32)
    contrib = y_sorted.astype(jnp.float32) * w_sorted[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[token_of].add(contrib)

    if mcfg.num_shared:
        from .layers import swiglu
        out = out + swiglu(params["shared"], xf).astype(jnp.float32)
    return out.astype(x.dtype).reshape(shape), aux


def moe_ffn_gather(params, x, mcfg: MoEConfig):
    """Capacity-based GATHER dispatch (§Perf, llama4 iteration 2).

    The dense one-hot dispatch einsum (``moe_ffn_dispatch``) is a
    T x (E*C) x d matmul — with E*C ~= 1.25*T*K it costs MORE than the
    expert FFN itself (refuted in EXPERIMENTS.md §Perf, llama4 iter 1).
    Here dispatch is a zero-FLOP slot gather: ``slot_token[e, c]`` holds
    the token occupying expert e's slot c (sentinel T = dropped/empty ->
    gathers a zero row), the expert FFN runs as (E, C, d) batch matmuls
    whose E dim aligns with the expert sharding, and tokens read their
    results back with a (T, K) gather + weighted sum."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    E, K = mcfg.num_experts, mcfg.top_k
    C = max(1, int(np.ceil(T * K / E * mcfg.capacity_factor)))
    topw, topi, aux = _route(params, xf, mcfg)

    # slot assignment per (t, k): position within the routed expert
    used = jnp.zeros((E,), jnp.int32)
    slot_token = jnp.full((E * C,), T, jnp.int32)        # sentinel: zero row
    pos_tk = jnp.zeros((T, K), jnp.int32)
    keep_tk = jnp.zeros((T, K), bool)
    for k in range(K):
        oh = jax.nn.one_hot(topi[:, k], E, dtype=jnp.int32)       # (T, E)
        pos = jnp.cumsum(oh, axis=0) - oh + used[None, :]
        mypos = jnp.sum(pos * oh, axis=1)                         # (T,)
        keep = mypos < C
        flat = jnp.where(keep, topi[:, k] * C + mypos, E * C)
        slot_token = slot_token.at[jnp.clip(flat, 0, E * C - 1)].set(
            jnp.where(keep, jnp.arange(T, dtype=jnp.int32),
                      slot_token[jnp.clip(flat, 0, E * C - 1)]))
        pos_tk = pos_tk.at[:, k].set(mypos)
        keep_tk = keep_tk.at[:, k].set(keep)
        used = used + jnp.sum(oh * keep[:, None], axis=0)

    from .layers import maybe_constrain
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    x_e = jnp.take(xpad, slot_token, axis=0).reshape(E, C, d)
    # pin the expert dim to the EP ('model') axis — without the constraint
    # GSPMD replicated the expert batch-matmuls 16x (EXPERIMENTS.md §Perf,
    # llama4 iteration 3)
    x_e = maybe_constrain(x_e, "model", None, None)
    g = jnp.einsum("ecd,edf->ecf", x_e, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x_e, params["w_up"])
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(x.dtype)
    h = maybe_constrain(h, "model", None, None)
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])         # (E,C,d)
    y_e = maybe_constrain(y_e, "model", None, None)

    # read-back: token t sums its kept slots, weighted by the router
    flat_idx = jnp.clip(topi * C + pos_tk, 0, E * C - 1)          # (T, K)
    y_tk = jnp.take(y_e.reshape(E * C, d), flat_idx, axis=0)      # (T,K,d)
    w = (topw * keep_tk).astype(jnp.float32)
    out = jnp.einsum("tkd,tk->td", y_tk.astype(jnp.float32), w)

    if mcfg.num_shared:
        from .layers import swiglu
        out = out + swiglu(params["shared"], xf).astype(jnp.float32)
    return out.astype(x.dtype).reshape(shape), aux


def moe_ffn_dispatch(params, x, mcfg: MoEConfig):
    """Capacity-based dense-dispatch MoE (§Perf, EXPERIMENTS.md llama4).

    Builds (T, E, C) dispatch/combine tensors whose E dim aligns with the
    expert-sharded weight stacks, so under GSPMD each EP shard contracts
    the full (replicated-over-model) token block against its local experts
    — no expert-weight all-gathers, no layout ping-pong; the only
    model-axis collective is the final combine all-reduce of (T, d)
    activations.  Tokens beyond ``capacity_factor * T * K / E`` per expert
    are dropped (standard production behaviour; the dropless ragged path
    remains the numerical default)."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    E, K = mcfg.num_experts, mcfg.top_k
    C = max(1, int(np.ceil(T * K / E * mcfg.capacity_factor)))
    topw, topi, aux = _route(params, xf, mcfg)

    disp = jnp.zeros((T, E, C), xf.dtype)
    comb = jnp.zeros((T, E, C), jnp.float32)
    # fill slots per routing rank k (K small: python loop, no (T,K,E,C))
    used = jnp.zeros((E,), jnp.int32)          # slots consumed per expert
    for k in range(K):
        oh = jax.nn.one_hot(topi[:, k], E, dtype=jnp.int32)       # (T, E)
        pos = jnp.cumsum(oh, axis=0) - oh + used[None, :]         # (T, E)
        keep = (pos < C) & (oh > 0)
        slot = jax.nn.one_hot(jnp.where(keep, pos, C), C,
                              dtype=xf.dtype)                     # (T,E,C)
        slot = slot * keep[..., None]
        disp = disp + slot
        comb = comb + slot.astype(jnp.float32) \
            * topw[:, k][:, None, None]
        used = used + jnp.sum(oh * keep, axis=0)

    x_e = jnp.einsum("tec,td->ecd", disp, xf)                     # (E,C,d)
    g = jnp.einsum("ecd,edf->ecf", x_e, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x_e, params["w_up"])
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(x.dtype)
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])         # (E,C,d)
    out = jnp.einsum("ecd,tec->td", y_e.astype(jnp.float32), comb)

    if mcfg.num_shared:
        from .layers import swiglu
        out = out + swiglu(params["shared"], xf).astype(jnp.float32)
    return out.astype(x.dtype).reshape(shape), aux
