"""Semantic join discovery (paper §I): find joinable table columns whose
*values* are semantically related even when they never match exactly —
the BigApple/NewYorkCity scenario of the paper's Fig. 1.

We model a data lake as columns = token sets.  A clean "city names" query
column is searched against (a) an exact copy, (b) a dirty copy (synonyms:
tokens replaced by same-cluster neighbours), (c) unrelated columns.
Vanilla overlap ranks the dirty copy poorly; semantic overlap recovers it.

    PYTHONPATH=src python examples/semantic_join.py
"""
import numpy as np

from repro.core import (EmbeddingSimilarity, KoiosSearch, SearchParams,
                        SetCollection)
from repro.data import make_embeddings

rng = np.random.default_rng(0)
VOCAB, DIM = 3000, 64
table = make_embeddings(VOCAB, dim=DIM, cluster_size=4.0, intra_cos=0.9,
                        seed=0)
sim = EmbeddingSimilarity(table)

# synonym map: nearest same-cluster neighbour >= 0.8
sims = table @ table.T
np.fill_diagonal(sims, 0)
synonym = sims.argmax(1)
has_syn = sims.max(1) >= 0.8

query_col = rng.choice(VOCAB, size=24, replace=False)

columns = []
labels = []
# (a) exact duplicate
columns.append(query_col.copy())
labels.append("exact duplicate")
# (b) dirty copies: 60% of values replaced by synonyms, rest exact
for frac, name in [(0.4, "dirty copy (40% synonyms)"),
                   (0.8, "dirty copy (80% synonyms)")]:
    col = query_col.copy()
    swap = rng.random(len(col)) < frac
    col[swap & has_syn[col]] = synonym[col][swap & has_syn[col]]
    columns.append(np.unique(col))
    labels.append(name)
# (c) unrelated columns
for i in range(40):
    columns.append(rng.choice(VOCAB, size=rng.integers(10, 30),
                              replace=False))
    labels.append(f"random column {i}")

indptr = np.zeros(len(columns) + 1, np.int64)
np.cumsum([len(c) for c in columns], out=indptr[1:])
coll = SetCollection(indptr, np.concatenate(columns).astype(np.int32),
                     VOCAB)

engine = KoiosSearch(coll, sim, SearchParams(k=5, alpha=0.8))
res = engine.search(query_col)

print(f"query column: {len(query_col)} values")
print("top-5 joinable columns by SEMANTIC overlap:")
for sid, score in zip(res.ids, res.lb):
    vanilla = len(np.intersect1d(query_col, coll.get_set(int(sid))))
    print(f"  {labels[sid]:28s} SO={score:5.2f}  vanilla={vanilla}")
print("\n(vanilla overlap alone would rank the dirty copies below any "
      "random column with a lucky exact match — semantic overlap "
      "recovers them, the paper's §I example)")
