"""Pallas pieces of the fused refine+verify wave program (DESIGN.md §3).

The on-device wave program (``repro.core.wave``) chains the refinement
chunk scan into the first verification rounds without a host round-trip.
Two device primitives live here because they are shared by that program
and by standalone callers:

* ``compact_indices`` — candidate compaction by prefix-sum mask.  The
  refinement scan ends with a (num_sets,) survivor mask; verification
  wants the survivor *indices* in ascending order (the host path's
  ``mask.nonzero()[0]``).  The kernel computes an inclusive prefix sum
  over the mask, derives every element's target slot (survivors first,
  both groups in ascending index order), and writes the inverse
  permutation with a sequential ``pl.store`` loop — dynamic scalar
  stores lower on Mosaic where a vector scatter would not.  One grid
  step, (1, n) blocks: n int32 lanes in + n out, ~8 KB per 1k sets —
  VMEM is never the constraint at repository-partition sizes.

* ``candidate_weights`` — the verification weight tensor for one round's
  candidate batch, computed from the *normalized* embedding table so the
  per-entry math (a d-dim dot product, clip to [0, 1], identity pairs
  forced to 1.0, alpha-threshold) is element-for-element the computation
  ``VerifierPool.weights_for_requests`` runs on the host.  Pure jnp: the
  contraction is MXU work already; fusing it buys nothing a matmul
  doesn't.

Both have pure-jnp oracles in ``ref.py`` and interpret-mode dispatch in
``ops.py`` (the repo-wide kernel convention, DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compact_kernel(mask_ref, idx_ref, cnt_ref, *, n: int):
    m = mask_ref[...]                                  # (1, n) int32 0/1
    ps = jnp.cumsum(m, axis=1)                         # inclusive prefix sum
    total = ps[0, n - 1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    # survivor i -> slot ps[i]-1; dropped i -> slot total + (i - ps[i]):
    # both groups keep ascending index order, so slots form a permutation
    pos = jnp.where(m > 0, ps - 1, total + iota - ps)[0]
    val = jnp.where(m[0] > 0, iota[0], jnp.int32(-1))

    def body(i, _):
        pl.store(idx_ref, (slice(0, 1), pl.dslice(pos[i], 1)),
                 val[i].reshape(1, 1))
        return 0

    jax.lax.fori_loop(0, n, body, 0)
    cnt_ref[...] = total.reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def compact_indices(mask: jnp.ndarray, interpret: bool = False):
    """Survivor indices of a boolean mask, ascending, -1 beyond the count.

    mask: (n,) bool.  Returns (idx (n,) int32, count () int32) with
    ``idx[:count]`` == ``mask.nonzero()[0]`` and ``idx[count:] == -1``.
    """
    n = mask.shape[0]
    idx, cnt = pl.pallas_call(
        functools.partial(_compact_kernel, n=n),
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(mask.astype(jnp.int32)[None, :])
    return idx[0], cnt[0, 0]


def candidate_weights(table_n: jnp.ndarray, query_tok: jnp.ndarray,
                      cand_tok: jnp.ndarray, cand_sizes: jnp.ndarray,
                      nq: jnp.ndarray, alpha) -> jnp.ndarray:
    """Alpha-thresholded verification weights for one candidate batch.

    table_n: (vocab, d) row-L2-normalized embedding table (normalizing the
      full table row-wise equals normalizing any row subset, so entries
      match the host pool's per-call ``_cosine_block`` bit for bit).
    query_tok: (nq_pad,) int32, -1 padding;  cand_tok: (vb, c_pad) int32,
      -1 padding;  cand_sizes: (vb,) logical |C|;  nq: logical |Q|.
    Returns (vb, nq_pad, c_pad) float32, zero outside the logical block.
    """
    qv = table_n[jnp.clip(query_tok, 0, None)]         # (nq_pad, d)
    tv = table_n[jnp.clip(cand_tok, 0, None)]          # (vb, c_pad, d)
    s = jnp.clip(jnp.einsum("qd,bcd->bqc", qv, tv,
                            preferred_element_type=jnp.float32), 0.0, 1.0)
    q_valid = query_tok >= 0
    t_valid = cand_tok >= 0
    same = (query_tok[None, :, None] == cand_tok[:, None, :]) \
        & q_valid[None, :, None] & t_valid[:, None, :]
    s = jnp.where(same, 1.0, s)
    w = jnp.where(s >= alpha, s, 0.0)
    row_ok = jnp.arange(query_tok.shape[0]) < nq
    col_ok = jnp.arange(cand_tok.shape[1])[None, :] < cand_sizes[:, None]
    return jnp.where(row_ok[None, :, None] & col_ok[:, None, :], w, 0.0)
