"""Kernel microbenchmarks: us/call of the Pallas kernels (interpret mode on
CPU — correctness-path timing; TPU wall-times come from the roofline
analysis) and their jnp oracles.

The refinement-scan rows are the PR-5 tentpole's A/B: the serial
per-event admission loop vs the set-segmented parallel scan (lane-packed
levels), on a broad multi-set stream (the serving-typical shape, where
level widths are large and the segmented depth is a small fraction of
the chunk) AND on a skewed one-set-heavy stream (the worst case, where
one deep segment pins the sequential depth near the chunk length).  The
Pallas `refine_events` arm runs in interpret mode — dispatch-bound on
CPU; its TPU story is the VMEM-resident carry.

Rows are also written to ``BENCH_kernels.json`` (CI artifact) so the
kernel-level perf trajectory accumulates across commits; ``--json ''``
disables."""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import (auction_topk2, auction_topk2_ref, cosine_topk,
                           cosine_topk_ref, refine_events, ssd, ssd_ref)

from .common import csv_line


def _time(fn, *args, reps=5):
    fn(*args)                     # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)
    return (time.time() - t0) / reps * 1e6


def _refinement_rows():
    """Serial per-event loop vs segmented scan vs Pallas-interpret on
    REAL bench-preset posting streams (the zipf posting skew is what the
    lane packing exploits — synthetic uniform streams misrepresent both
    layouts).  ``wdc`` is the deep-stream case the segmented scan wins
    outright; ``opendata`` is the skew-dominated small-stream case where
    one long per-set segment pins the sequential depth (the honest
    worst case)."""
    from repro.core import InvertedIndex, build_token_stream, \
        expand_to_events
    from repro.core.refinement import run_refinement
    from repro.core.token_stream import pack_events_segmented, pad_events
    from repro.data import sample_queries

    from .common import world

    rows = []
    for name in ("wdc", "opendata"):
        coll, sim = world(name)
        inv = InvertedIndex.build(coll)
        qs = sample_queries(coll, 4, seed=11)
        evs = [expand_to_events(build_token_stream(q, sim, 0.8), inv)
               for q in qs]
        i = int(np.argmax([len(e) for e in evs]))
        ev, q = evs[i], qs[i]
        nq, total_slots, sizes = len(q), coll.total_tokens, coll.set_sizes
        derived = f"{name} E={len(ev)} sets={coll.num_sets} chunk=256"
        for layout in ("serial", "segmented"):
            us = _time(lambda layout=layout: run_refinement(
                ev, sizes, nq, total_slots, 10, 0.8, 256, "sound",
                layout=layout), reps=20)
            rows.append((f"refine_scan_{layout}_{name}", us, derived))
        # Pallas kernel arm: admission of the packed chunks (interpret
        # mode — dispatch-bound on CPU; the TPU pitch is the
        # VMEM-resident carry)
        s3, q3, sl3, si3, _ = pack_events_segmented(*pad_events(ev, 256))
        from repro.core.refinement import refine_carry_init
        qw = max(1, -(-nq // 32))
        state = refine_carry_init(coll.num_sets, qw, total_slots)[:-1]

        def kernel_chain(state=state, s3=s3, q3=q3, sl3=sl3, si3=si3):
            st = state
            for c in range(s3.shape[0]):
                out = refine_events(st, s3[c], q3[c], sl3[c], si3[c])
                st = out[:5] + (st[5],) + out[5:]
            return st

        rows.append((f"refine_events_interp_{name}", _time(kernel_chain, reps=1),
                     derived + " (admission only)"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="perf-artifact path ('' disables)")
    args = ap.parse_args(argv)
    rng = np.random.default_rng(0)
    rows = []

    qe = rng.normal(size=(16, 64)).astype(np.float32)
    ev = rng.normal(size=(2048, 64)).astype(np.float32)
    qe /= np.linalg.norm(qe, axis=1, keepdims=True)
    ev /= np.linalg.norm(ev, axis=1, keepdims=True)
    rows.append(("cosine_topk_interp",
                 _time(lambda: cosine_topk(qe, ev, k=16, bv=256)),
                 "nq=16 nv=2048 d=64 k=16"))
    rows.append(("cosine_topk_ref",
                 _time(lambda: cosine_topk_ref(jnp.asarray(qe),
                                               jnp.asarray(ev), 16)),
                 "jnp oracle"))

    wm = rng.random((256, 512)).astype(np.float32)
    pr = rng.random(512).astype(np.float32)
    rows.append(("auction_topk2_interp",
                 _time(lambda: auction_topk2(wm, pr, bn=128)),
                 "n=256 m=512"))
    rows.append(("auction_topk2_ref",
                 _time(lambda: auction_topk2_ref(jnp.asarray(wm),
                                                 jnp.asarray(pr))),
                 "jnp oracle"))

    Bt, L, H, P, G, S = 1, 64, 4, 16, 1, 16
    x = rng.normal(size=(Bt, L, H, P)).astype(np.float32)
    dt = np.log1p(np.exp(rng.normal(size=(Bt, L, H)))).astype(np.float32)
    A = (-np.exp(rng.normal(size=H))).astype(np.float32)
    B = (rng.normal(size=(Bt, L, G, S)) / 4).astype(np.float32)
    C = (rng.normal(size=(Bt, L, G, S)) / 4).astype(np.float32)
    D = rng.normal(size=H).astype(np.float32)
    rows.append(("ssd_interp",
                 _time(lambda: ssd(x, dt, A, B, C, D, chunk=16)),
                 f"B={Bt} L={L} H={H} P={P} S={S}"))
    rows.append(("ssd_ref",
                 _time(lambda: ssd_ref(jnp.asarray(x[0]), jnp.asarray(dt[0]),
                                       jnp.asarray(A), jnp.asarray(B[0]),
                                       jnp.asarray(C[0]), jnp.asarray(D))),
                 "sequential oracle"))

    rows.extend(_refinement_rows())

    for name, us, derived in rows:
        print(csv_line(name, us, derived))

    if args.json:
        doc = {"benchmark": "kernels",
               "rows": [{"name": n, "us_per_call": us, "derived": d}
                        for n, us, d in rows]}
        serial = {n: us for n, us, _ in rows
                  if n.startswith("refine_scan_serial")}
        seg = {n: us for n, us, _ in rows
               if n.startswith("refine_scan_segmented")}
        doc["refine_speedup_wdc"] = (
            serial.get("refine_scan_serial_wdc", 0.0)
            / max(seg.get("refine_scan_segmented_wdc", 1.0), 1e-9))
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"[bench] wrote {args.json} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    main()
