"""End-to-end training driver.

Preemption-safe: restores the latest checkpoint on start (the data pipeline
is a pure function of the step counter, so a restart resumes the exact
token stream).  Elastic: ``--elastic`` re-plans the mesh from the currently
healthy device count (runtime/fault.py) and GSPMD resharding happens on
checkpoint load — a checkpoint written on any mesh restores onto any other.

Smoke scale (this CPU container):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ck
Production scale: same driver, --mesh 16x16 (or 2x16x16 multi-pod) on a
real fleet."""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..data.synthetic import DataConfig, SyntheticLM
from ..models import build
from ..optim import AdamWConfig
from ..runtime.fault import plan_elastic_mesh
from ..runtime.sharding import input_pspecs, to_shardings
from .mesh import make_mesh, single_device_mesh
from .steps import make_train_step


def _parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    names = {1: ("data",), 2: ("data", "model"),
             3: ("pod", "data", "model")}[len(dims)]
    return dims, names


def make_batch_fn(cfg, data: SyntheticLM, frontend_rng):
    """Host batch -> model inputs (incl. modality-stub embeddings)."""
    def fn(step: int):
        b = data.global_batch(step)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        if cfg.family in ("vlm", "audio"):
            key = "prefix" if cfg.family == "vlm" else "frames"
            n = batch["tokens"].shape[0]
            batch[key] = jnp.asarray(frontend_rng.normal(
                size=(n, max(cfg.frontend_len, 1), cfg.d_model)),
                jnp.float32)
        return batch
    return fn


def train(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--elastic", action="store_true",
                    help="re-plan mesh from the healthy device count")
    ap.add_argument("--opt-state-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dims, names = _parse_mesh(args.mesh)
    if args.elastic:
        planned = plan_elastic_mesh(len(jax.devices()),
                                    dims[-1] if len(dims) > 1 else 1)
        if planned is None:
            raise SystemExit("not enough healthy devices")
        dims, names = planned, ("data", "model")
        print(f"[elastic] mesh -> {dims}")
    mesh = make_mesh(dims, names)

    opt_cfg = AdamWConfig(lr=args.lr, state_dtype=args.opt_state_dtype)
    train_step, model, state_specs, state_ps = make_train_step(
        cfg, mesh, opt_cfg, warmup=max(args.steps // 10, 1),
        total_steps=args.steps)

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))
    batch_fn = make_batch_fn(cfg, data, np.random.default_rng(0))
    batch0 = batch_fn(0)
    batch_ps = input_pspecs(
        jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0),
        mesh.axis_names, dict(mesh.shape))

    in_sh = (to_shardings(state_ps, mesh), to_shardings(batch_ps, mesh))
    out_sh = (to_shardings(state_ps, mesh), None)
    step_jit = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=0)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    state = None
    if mgr is not None:
        latest = mgr.restore_latest()
        if latest is not None:
            start_step, host_state, meta = latest
            print(f"[restore] step {start_step} (mesh was {meta.get('mesh')})")
            state = jax.tree_util.tree_map(
                lambda x, sh: jax.device_put(x, sh), host_state,
                to_shardings(state_ps, mesh))
    if state is None:
        from ..optim import adamw_init
        with mesh:
            params = jax.jit(
                model.init,
                out_shardings=to_shardings(state_ps["params"], mesh))(
                    jax.random.key(0))
            opt = jax.jit(
                lambda p: adamw_init(p, opt_cfg),
                out_shardings=to_shardings(state_ps["opt"], mesh))(params)
        state = {"params": params, "opt": opt}

    losses = []
    with mesh:
        for step in range(start_step, args.steps):
            t0 = time.time()
            state, metrics = step_jit(state, batch_fn(step))
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"dt {time.time()-t0:.2f}s", flush=True)
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, state,
                         {"mesh": list(dims), "arch": args.arch})
    if mgr is not None:
        mgr.save(args.steps, state, {"mesh": list(dims), "arch": args.arch})
        mgr.wait()
    return losses


if __name__ == "__main__":
    train()
