"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

Assigned: 24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
[arXiv:2308.11596; hf]

24 encoder + 24 decoder layers at the assigned width (seamless large: 24L
speech encoder / 24L text decoder).  The speech frontend is a STUB:
input_specs() provides precomputed frame embeddings (batch, 1024, d_model)
as encoder input."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio", num_layers=24,
    enc_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, d_ff=8192,
    vocab_size=256206, frontend="audio", frontend_len=1024)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="audio", num_layers=2, enc_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
        frontend="audio", frontend_len=8, dtype="float32", remat="none")
