from .sets import (make_collection, make_embeddings, dataset_preset,
                   sample_queries, PRESETS)
from .embeddings import EmbeddingTableProvider

__all__ = [
    "make_collection", "make_embeddings", "dataset_preset", "sample_queries",
    "PRESETS", "EmbeddingTableProvider",
]
