"""Render the §Roofline table from the dry-run JSON records
(experiments/dryrun/*.json): per (arch x shape) the three roofline terms,
the dominant bottleneck, MODEL_FLOPS ratio, and memory fit."""
from __future__ import annotations

import glob
import json
import os

HBM_PER_CHIP = 16e9      # v5e


def load(dry_dir="experiments/dryrun", mesh="single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dry_dir, f"*_{mesh}.json"))):
        r = json.load(open(path))
        rows.append(r)
    return rows


def table(dry_dir="experiments/dryrun"):
    out = []
    for r in load(dry_dir):
        base = {"arch": r["arch"], "shape": r["shape"],
                "status": r["status"]}
        if r["status"] == "skipped":
            base["note"] = r["reason"]
            out.append(base)
            continue
        if r["status"] == "error":
            base["note"] = r.get("error", "")[:80]
            out.append(base)
            continue
        rf = r.get("roofline", {})
        mem = r["production"]["memory"]
        base.update({
            "compute_s": rf.get("compute_s"),
            "memory_s": rf.get("memory_s"),
            "collective_s": rf.get("collective_s"),
            "bottleneck": rf.get("bottleneck"),
            "roofline_fraction": rf.get("roofline_fraction"),
            "useful_ratio": rf.get("useful_compute_ratio"),
            "model_flops_G": (rf.get("model_flops_global", 0) / 1e9),
            "arg_gb": mem["argument_bytes"] / 1e9,
            "fits_hbm": (mem["argument_bytes"] + mem["output_bytes"])
            < HBM_PER_CHIP,
            "compile_s": r.get("compile_s"),
        })
        out.append(base)
    return out


def main():
    rows = table()
    hdr = ("arch,shape,status,bottleneck,compute_s,memory_s,collective_s,"
           "roofline_frac,useful_ratio,arg_GB,compile_s")
    print(hdr)
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']},{r['shape']},{r['status']},"
                  f"{r.get('note','')}")
            continue

        def f(x, p=4):
            return "" if x is None else f"{x:.{p}f}"
        print(f"{r['arch']},{r['shape']},{r['status']},{r['bottleneck']},"
              f"{f(r['compute_s'])},{f(r['memory_s'])},"
              f"{f(r['collective_s'])},{f(r['roofline_fraction'],3)},"
              f"{f(r['useful_ratio'],3)},{f(r['arg_gb'],2)},"
              f"{r['compile_s']}")


if __name__ == "__main__":
    main()
