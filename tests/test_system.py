"""End-to-end system behaviour: train a small embedding tower, plug it into
KOIOS as the similarity provider, search, and verify exactness — the full
story of the framework in one test (paper technique + training substrate +
serving path)."""
import numpy as np
import pytest

from repro.core import (EmbeddingSimilarity, KoiosIndex, KoiosSearch,
                        SearchParams, brute_force_topk)
from repro.data import make_collection, sample_queries
from repro.data.embeddings import tower_embeddings
from repro.launch.train import train


@pytest.fixture(scope="module")
def trained_params(tmp_path_factory):
    ckpt = tmp_path_factory.mktemp("ck")
    losses = train([
        "--arch", "tinyllama-1.1b", "--smoke", "--steps", "32",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(ckpt),
        "--ckpt-every", "32", "--log-every", "100"])
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(ckpt))
    step, state, meta = mgr.restore_latest()
    return losses, state["params"]


def test_training_reduces_loss(trained_params):
    losses, _ = trained_params
    # window of 8: single-step loss is noisy at batch 4 (the seed's 4-step
    # window flaked); the 8-step means separate cleanly after 32 steps
    assert np.mean(losses[-8:]) < np.mean(losses[:8])


def test_trained_tower_drives_search(trained_params):
    """The trained tower's embedding table is a valid KOIOS similarity
    provider and the search stays exact under it."""
    _, params = trained_params
    table = tower_embeddings(params)
    vocab = table.shape[0]
    coll = make_collection(num_sets=60, vocab_size=vocab, avg_size=6,
                           max_size=12, seed=3)
    sim = EmbeddingSimilarity(table)
    sp = SearchParams(k=3, alpha=0.8, chunk_size=64, verify_batch=8)
    engine = KoiosSearch(coll, sim, sp)
    index = KoiosIndex.build(coll)
    q = sample_queries(coll, 1, seed=4)[0]
    res = engine.search(q)
    ref = brute_force_topk(index, q, sim, sp)
    assert np.allclose(np.sort(res.lb), np.sort(ref.lb[:len(res.lb)]),
                       atol=1e-3)


def test_restart_resumes(trained_params, tmp_path):
    """Preemption safety: a second run with more steps resumes from the
    checkpoint instead of starting over."""
    train(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "6",
           "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
           "--ckpt-every", "3", "--log-every", "100"])
    more = train([
        "--arch", "tinyllama-1.1b", "--smoke", "--steps", "8",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "3", "--log-every", "100"])
    assert len(more) == 2      # resumed at step 6, ran 6..8
