"""Baselines of the paper §VIII-A4.

* ``brute_force_topk``  — the exact oracle: every set's SO via Hungarian
  (used by tests as ground truth on small inputs).
* ``baseline_topk``     — the paper's Baseline: token stream identifies
  candidate sets (>= one element with sim >= alpha), then every candidate
  is verified by exact graph matching (the paper parallelizes this with a
  thread pool; we batch it).
* ``baseline_plus_topk`` — Baseline+ : same, but with the iUB-filter active
  during refinement (used for WDC-scale workloads in the paper).

All reuse KOIOS' machinery with the filters disabled so that measured
speedups isolate exactly the paper's contribution.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from .matching.hungarian import hungarian_batch
from .postprocess import Verifier, _pad_pow2
from .refinement import run_refinement
from .search import KoiosIndex, merge_topk
from .token_stream import build_token_stream, expand_to_events
from .types import SearchParams, SearchResult, SearchStats


def _verify_all(index: KoiosIndex, query, sim_provider, ids, params,
                stats) -> SearchResult:
    verifier = Verifier(index.coll, query, sim_provider, params)
    scores = np.zeros(len(ids), np.float64)
    B = params.verify_batch
    for lo in range(0, len(ids), B):
        batch = ids[lo:lo + B]
        lb, ub, _ = verifier.verify(batch, -np.inf)
        scores[lo:lo + B] = lb
    stats.exact_matches += verifier.stats_em_full
    order = np.argsort(-scores, kind="stable")[:params.k]
    return SearchResult(
        ids=(np.asarray(ids)[order] + index.id_offset).astype(np.int32),
        lb=scores[order].astype(np.float32),
        ub=scores[order].astype(np.float32),
        stats=stats)


def baseline_topk(index: KoiosIndex, query: np.ndarray, sim_provider,
                  params: SearchParams) -> SearchResult:
    """Paper Baseline: verify every candidate set."""
    query = np.asarray(query, np.int32)
    params = dataclasses.replace(params, verifier="hungarian")
    stream = build_token_stream(query, sim_provider, params.alpha)
    events = expand_to_events(stream, index.inv)
    stats = SearchStats(stream_tuples=len(stream), stream_events=len(events))
    cand = np.unique(events.set_id)
    stats.candidates = len(cand)
    return _verify_all(index, query, sim_provider, cand, params, stats)


def baseline_plus_topk(index: KoiosIndex, query: np.ndarray, sim_provider,
                       params: SearchParams) -> SearchResult:
    """Baseline+ : iUB-filter during refinement, then verify all survivors."""
    query = np.asarray(query, np.int32)
    params = dataclasses.replace(params, verifier="hungarian")
    coll = index.coll
    stream = build_token_stream(query, sim_provider, params.alpha)
    events = expand_to_events(stream, index.inv)
    if len(events) == 0:
        return SearchResult(ids=np.zeros(0, np.int32),
                            lb=np.zeros(0, np.float32),
                            ub=np.zeros(0, np.float32), stats=SearchStats())
    ref = run_refinement(events, coll.set_sizes, len(query),
                         coll.total_tokens, params.k, params.alpha,
                         params.chunk_size, params.ub_mode)
    surv = (ref.seen & ref.alive).nonzero()[0]
    return _verify_all(index, query, sim_provider, surv, params, ref.stats)


def brute_force_topk(index: KoiosIndex, query: np.ndarray, sim_provider,
                     params: SearchParams) -> SearchResult:
    """Exact oracle over *all* sets (tests only — O(num_sets * n^3))."""
    query = np.asarray(query, np.int32)
    params = dataclasses.replace(params, verifier="hungarian")
    stats = SearchStats()
    all_ids = np.arange(index.coll.num_sets)
    return _verify_all(index, query, sim_provider, all_ids, params, stats)
