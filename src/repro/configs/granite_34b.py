"""granite-34b [dense] — llama-arch, code; MQA (kv=1).

Assigned: 88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
[arXiv:2405.04324; hf]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense", num_layers=88, d_model=6144,
    num_heads=48, num_kv_heads=1, d_ff=24576, vocab_size=49152)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=512,
        dtype="float32", remat="none")
