"""Jit-recompilation guards: DESIGN.md §2 promises that pow2 padding
everywhere (chunk counts, bitmask words, solver batches, wave shapes)
bounds the number of compiled program variants to O(log shape).  These
tests sweep input sizes across orders of magnitude and count the actual
jit cache growth."""
import math

import numpy as np
import pytest

from repro.core import EmbeddingSimilarity, KoiosSearch, SearchParams
from repro.core.refinement import _run_refinement, run_refinement
from repro.core.token_stream import EventStream
from repro.data import make_collection, make_embeddings, sample_queries


def _synthetic_events(rng, n_events: int, num_sets: int, nq: int,
                      total_slots: int) -> EventStream:
    sim = np.sort(rng.random(n_events).astype(np.float32))[::-1]
    return EventStream(
        set_id=rng.integers(0, num_sets, n_events).astype(np.int32),
        q_pos=rng.integers(0, nq, n_events).astype(np.int32),
        slot=rng.integers(0, total_slots, n_events).astype(np.int64),
        sim=sim, n_tuples=n_events)


def test_refinement_variants_log_in_stream_length():
    """Stream lengths across 3 orders of magnitude compile O(log) scan
    variants: pow2 chunk counts, plus the segmented layout's pow2
    (W, L) lane grid — both lane dims are bounded by the (fixed) chunk
    size, so the growth in STREAM LENGTH stays the chunk-count log and
    the grid contributes a small additive factor.  A second sweep of
    the same lengths must compile nothing (the bucketing is the point)."""
    rng = np.random.default_rng(0)
    num_sets, nq, total_slots, chunk = 50, 8, 400, 64
    sizes = rng.integers(2, 12, num_sets).astype(np.int64)
    sizes = np.minimum(sizes, total_slots // num_sets)
    before = _run_refinement._cache_size()
    lengths = [1, 3, 7, 20, 55, 130, 300, 701, 1500, 2500]

    def sweep():
        sweep_rng = np.random.default_rng(1)
        for L in lengths:
            ev = _synthetic_events(sweep_rng, L, num_sets, nq, total_slots)
            run_refinement(ev, sizes.astype(np.int32), nq, total_slots,
                           k=5, alpha=0.8, chunk_size=chunk)

    sweep()
    variants = _run_refinement._cache_size() - before
    max_chunks = -(-max(lengths) // chunk)
    # pow2 chunk counts + the pow2 lane grid at this (fixed) chunk size
    bound = math.ceil(math.log2(max_chunks)) + 2 \
        + math.ceil(math.log2(chunk))
    assert variants <= bound, (variants, bound)
    mid = _run_refinement._cache_size()
    sweep()                              # identical shapes: no growth
    assert _run_refinement._cache_size() == mid


def test_engine_sweep_compiles_olog(small_world):
    """End-to-end: a sweep of query cardinalities (and thus stream/solver
    shapes) through the engine stays within an O(log) compile budget for
    the refinement scan and both solver entry points."""
    from repro.core.matching.auction import auction_batch
    from repro.core.matching.hungarian import hungarian_batch

    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          verifier="hybrid")
    engine = KoiosSearch(coll, sim, params, partitions=2)
    rng = np.random.default_rng(2)
    sweep = [1, 2, 3, 5, 8, 11, 16, 23, 32]
    queries = [np.asarray(rng.choice(coll.vocab_size, size=nq,
                                     replace=False), np.int32)
               for nq in sweep]
    before = (_run_refinement._cache_size(),
              auction_batch._cache_size(), hungarian_batch._cache_size())
    for q in queries:
        engine.search(q, schedule="overlap")
    grew = (_run_refinement._cache_size() - before[0],
            auction_batch._cache_size() - before[1],
            hungarian_batch._cache_size() - before[2])
    # 9 distinct |Q| values with streams spanning ~2 orders of magnitude.
    # Every padded dim is pow2, so variant counts are bounded by products
    # of log factors (nq_pad in {8,16,32} x c_pad in {8,16,32} at this
    # scale, plus the segmented layout's pow2 lane grid at the fixed
    # chunk size), never by the number of distinct logical shapes seen.
    assert grew[0] <= math.ceil(math.log2(1 + 2500 // 64)) + 2 \
        + 2 * math.ceil(math.log2(64)), grew
    assert grew[1] <= 3 * 3 + 1, grew          # (nq_pad x c_pad) grid
    assert grew[2] <= 3 * 3 + 1, grew
    # the actual recompile guard: a second identical sweep compiles NOTHING
    mid = (_run_refinement._cache_size(),
           auction_batch._cache_size(), hungarian_batch._cache_size())
    for q in queries:
        engine.search(q, schedule="overlap")
    assert (_run_refinement._cache_size(),
            auction_batch._cache_size(),
            hungarian_batch._cache_size()) == mid


def _jit_cache_sizes():
    from repro.core.matching.auction import auction_batch
    from repro.core.matching.hungarian import hungarian_batch
    from repro.core.similarity import _cosine_block

    return (_run_refinement._cache_size(), auction_batch._cache_size(),
            hungarian_batch._cache_size(), _cosine_block._cache_size())


def test_engine_steady_state_zero_recompiles(small_world):
    """The request-engine tentpole invariant (DESIGN.md §3.2): after
    warmup, a steady-state serving sweep of VARYING batch sizes within
    one pow2 bucket — different cohort compositions, different verify
    round shapes, stream-cache hits and misses — compiles NOTHING:
    refinement scans, both solvers, and the provider similarity blocks
    all reuse pow2-bucketed programs."""
    from repro.runtime.engine import RequestEngine

    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          verifier="hybrid")
    pool = sample_queries(coll, 8, seed=3)
    sweep = [5, 6, 7, 8, 6, 5]           # one pow2 bucket (pads to 8)
    rng = np.random.default_rng(4)
    batches = [[pool[i] for i in rng.choice(8, size=bs, replace=False)]
               for bs in sweep]

    def serve_all():
        eng = RequestEngine(coll, sim, params, partitions=2)
        eng.warmup(pool)
        for batch in batches:
            eng.serve(batch)

    serve_all()                          # prime every bucketed shape
    before = _jit_cache_sizes()
    serve_all()                          # steady state: zero recompiles
    assert _jit_cache_sizes() == before


def test_fused_engine_steady_state_zero_recompiles(small_world):
    """Same invariant through the fused device-wave engine: wave configs
    depend only on pow2-padded shapes, so a steady-state sweep of batch
    sizes within one pow2 bucket reuses the compiled wave programs."""
    from repro.core.wave import _wave_fn
    from repro.runtime.engine import RequestEngine

    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          fused="interpret")
    pool = sample_queries(coll, 8, seed=3)
    batches = [pool[:bs] for bs in (5, 6, 7, 8, 6)]

    def serve_all():
        eng = RequestEngine(coll, sim, params, partitions=2,
                            schedule="fused")
        assert eng.schedule == "fused"
        for batch in batches:
            eng.serve(batch)

    serve_all()                          # prime the wave-config grid
    before = (_wave_fn.cache_info().currsize, _jit_cache_sizes())
    serve_all()                          # steady state: zero recompiles
    assert (_wave_fn.cache_info().currsize, _jit_cache_sizes()) == before


def test_sharded_engine_warmup_zero_steady_state_recompiles(small_world):
    """PR-6 invariant: engine warmup sweeps the SHARD-LOCAL pow2
    chunk-bucket grid (each shard's inverted index yields different
    event counts for the same query), so a 4-shard fused engine serving
    varying batch sizes within one pow2 bucket compiles NOTHING after
    warmup — wave programs, refinement scans, solvers, similarity
    blocks, and the top-k merge tree are all primed per shard."""
    from repro.core.search import _merge_tree_fn
    from repro.core.wave import _wave_fn
    from repro.runtime.collection import ShardedCollection
    from repro.runtime.engine import RequestEngine

    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          fused="interpret")
    sc = ShardedCollection.build(coll, 4)
    pool = sample_queries(coll, 8, seed=3)
    batches = [pool[:bs] for bs in (5, 6, 7, 8, 6)]

    eng = RequestEngine(None, sim, params, schedule="fused", collection=sc)
    assert eng.schedule == "fused"
    assert eng.collection is sc
    eng.warmup(pool)
    before = (_wave_fn.cache_info().currsize,
              _merge_tree_fn.cache_info().currsize, _jit_cache_sizes())
    for batch in batches:
        eng.serve(batch)
    assert (_wave_fn.cache_info().currsize,
            _merge_tree_fn.cache_info().currsize,
            _jit_cache_sizes()) == before


def test_fused_wave_variants_shared_across_batches(small_world):
    """The wave program's static config depends only on pow2-padded
    shapes: rerunning the fused schedule with a different batch of the
    same padded size must not recompile."""
    from repro.core.wave import _wave_fn

    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          fused="interpret")
    engine = KoiosSearch(coll, sim, params, partitions=2)
    q1 = sample_queries(coll, 3, seed=1)
    q2 = sample_queries(coll, 3, seed=2)
    engine.search_batch(q1, schedule="fused")
    n_fns = _wave_fn.cache_info().currsize
    engine.search_batch(q1, schedule="fused")       # same shapes: no growth
    assert _wave_fn.cache_info().currsize == n_fns
    engine.search_batch(q2, schedule="fused")       # new batch: pow2 reuse
    assert _wave_fn.cache_info().currsize <= n_fns + 2
