"""Step-function builders shared by the trainer, server and dry-run.

Each builder returns (step_fn, in_shardings, out_shardings, arg_specs) so
callers can ``jax.jit(step_fn, in_shardings=..., out_shardings=...)
.lower(*arg_specs).compile()`` — the dry-run path — or run it for real with
the same shardings (trainer/server)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models import build, input_specs
from ..models.config import SHAPES, ModelConfig
from ..optim import (AdamWConfig, adamw_init, adamw_update,
                     clip_by_global_norm, warmup_cosine)
from ..runtime.sharding import (guard_pspec, input_pspecs, opt_state_pspecs,
                                param_pspecs, to_shardings)


def make_train_step(cfg: ModelConfig, mesh: Mesh,
                    opt_cfg: Optional[AdamWConfig] = None,
                    warmup: int = 100, total_steps: int = 10_000):
    """Full training step: fwd + bwd + clip + schedule + AdamW update."""
    model = build(cfg)
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr_scale = warmup_cosine(opt["count"], warmup=warmup,
                                 total=total_steps)
        new_params, new_opt = adamw_update(grads, opt, params, opt_cfg,
                                           lr_scale=lr_scale)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr_scale": lr_scale}
        return {"params": new_params, "opt": new_opt}, metrics

    p_specs = model.param_specs()
    o_specs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), p_specs)
    state_specs = {"params": p_specs, "opt": o_specs}

    p_ps = param_pspecs(p_specs, mesh.axis_names, dict(mesh.shape),
                        head_dim=cfg.hd)
    state_ps = {"params": p_ps, "opt": opt_state_pspecs(o_specs, p_ps)}
    batch_specs = input_specs(cfg, "train_4k")
    return train_step, model, state_specs, state_ps


def train_shardings(cfg: ModelConfig, mesh: Mesh, state_ps, shape_name: str):
    batch_specs = input_specs(cfg, shape_name)
    batch_ps = input_pspecs(batch_specs, mesh.axis_names, dict(mesh.shape))
    in_sh = (to_shardings(state_ps, mesh), to_shardings(batch_ps, mesh))
    out_sh = (to_shardings(state_ps, mesh),
              to_shardings({"loss": P(), "grad_norm": P(),
                            "lr_scale": P()}, mesh))
    return batch_specs, in_sh, out_sh


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape_name: str):
    model = build(cfg)
    seq, batch, kind = SHAPES[shape_name]
    assert kind == "prefill"

    def prefill_step(params, batch_in):
        logits, caches = model.prefill(params, batch_in)
        return logits, caches

    p_specs = model.param_specs()
    p_ps = param_pspecs(p_specs, mesh.axis_names, dict(mesh.shape),
                        head_dim=cfg.hd)
    batch_specs = input_specs(cfg, shape_name)
    batch_ps = input_pspecs(batch_specs, mesh.axis_names, dict(mesh.shape))

    out_specs = jax.eval_shape(prefill_step, p_specs, batch_specs)
    # caches inherit the decode-cache rules
    cache_ps = input_pspecs({"caches": out_specs[1]}, mesh.axis_names,
                            dict(mesh.shape))["caches"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    logits_ps = guard_pspec(P(dp if dp else None, None, None),
                            out_specs[0].shape, mesh)
    in_sh = (to_shardings(p_ps, mesh), to_shardings(batch_ps, mesh))
    out_sh = (to_shardings(logits_ps, mesh), to_shardings(cache_ps, mesh))
    return prefill_step, (p_specs, batch_specs), in_sh, out_sh


def make_decode_step(cfg: ModelConfig, mesh: Mesh, shape_name: str):
    model = build(cfg)
    seq, batch, kind = SHAPES[shape_name]
    assert kind == "decode"

    def decode_step(params, caches, token, cache_index):
        return model.decode_step(params, caches, token, cache_index)

    p_specs = model.param_specs()
    p_ps = param_pspecs(p_specs, mesh.axis_names, dict(mesh.shape),
                        head_dim=cfg.hd)
    dstate = input_specs(cfg, shape_name)
    d_ps = input_pspecs(dstate, mesh.axis_names, dict(mesh.shape))

    out_specs = jax.eval_shape(decode_step, p_specs, dstate["caches"],
                               dstate["token"], dstate["cache_index"])
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    logits_ps = guard_pspec(P(dp if dp else None, None, None),
                            out_specs[0].shape, mesh)
    cache_out_ps = input_pspecs({"caches": out_specs[1]}, mesh.axis_names,
                                dict(mesh.shape))["caches"]
    in_sh = (to_shardings(p_ps, mesh),
             to_shardings(d_ps["caches"], mesh),
             to_shardings(d_ps["token"], mesh),
             to_shardings(d_ps["cache_index"], mesh))
    out_sh = (to_shardings(logits_ps, mesh),
              to_shardings(cache_out_ps, mesh))
    arg_specs = (p_specs, dstate["caches"], dstate["token"],
                 dstate["cache_index"])
    return decode_step, arg_specs, in_sh, out_sh
