"""End-to-end search exactness: KOIOS == brute force on every instance."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (EmbeddingSimilarity, KoiosIndex, KoiosSearch,
                        SearchParams, baseline_plus_topk, baseline_topk,
                        brute_force_topk)
from repro.data import make_collection, make_embeddings, sample_queries


def _score_multiset_equal(a, b, atol=1e-3):
    return np.allclose(np.sort(a), np.sort(b), atol=atol)


@pytest.fixture(scope="module")
def world(small_world):
    coll, sim = small_world
    return coll, sim, KoiosIndex.build(coll)


def test_koios_matches_brute_force(world, default_params):
    coll, sim, index = world
    engine = KoiosSearch(coll, sim, default_params)
    for q in sample_queries(coll, 3, seed=11):
        res = engine.search(q)
        ref = brute_force_topk(index, q, sim, default_params)
        assert _score_multiset_equal(res.lb, ref.lb[:len(res.lb)])


def test_koios_matches_baselines(world, default_params):
    coll, sim, index = world
    engine = KoiosSearch(coll, sim, default_params)
    q = sample_queries(coll, 1, seed=13)[0]
    res = engine.search(q)
    base = baseline_topk(index, q, sim, default_params)
    basep = baseline_plus_topk(index, q, sim, default_params)
    assert _score_multiset_equal(res.lb, base.lb[:len(res.lb)])
    assert _score_multiset_equal(res.lb, basep.lb[:len(res.lb)])


@pytest.mark.parametrize("verifier", ["hungarian", "hybrid", "auction"])
def test_verifier_modes_agree(world, default_params, verifier):
    coll, sim, index = world
    params = dataclasses.replace(default_params, verifier=verifier)
    engine = KoiosSearch(coll, sim, params)
    q = sample_queries(coll, 1, seed=17)[0]
    res = engine.search(q)
    ref = brute_force_topk(index, q, sim, default_params)
    assert _score_multiset_equal(res.lb, ref.lb[:len(res.lb)])


def test_partitions_share_theta(world, default_params):
    """Paper §VI scale-out: partitioned search returns the same top-k."""
    coll, sim, index = world
    single = KoiosSearch(coll, sim, default_params, partitions=1)
    multi = KoiosSearch(coll, sim, default_params, partitions=4)
    q = sample_queries(coll, 1, seed=19)[0]
    r1 = single.search(q)
    r4 = multi.search(q)
    assert _score_multiset_equal(r1.lb, r4.lb)


def test_vanilla_overlap_lower_bounds_so(world, default_params):
    """Lemma 1: |Q cap C| <= SO(Q, C) for every returned set."""
    coll, sim, index = world
    engine = KoiosSearch(coll, sim, default_params)
    q = sample_queries(coll, 1, seed=23)[0]
    res = engine.search(q)
    for sid, score in zip(res.ids, res.lb):
        vanilla = len(np.intersect1d(q, coll.get_set(int(sid))))
        assert vanilla <= score + 1e-4


def test_k_variants(world, default_params):
    """Larger k extends, never reorders, the head of the result."""
    coll, sim, index = world
    engine = KoiosSearch(coll, sim, default_params)
    q = sample_queries(coll, 1, seed=29)[0]
    r5 = engine.search(q, k=5)
    r10 = engine.search(q, k=10)
    np.testing.assert_allclose(r10.lb[:5], r5.lb, atol=1e-4)


def test_paper_ub_mode_runs(world, default_params):
    """Reproduction mode (paper's Lemma-6 filter) executes; exactness is NOT
    asserted because the bound is unsound (DESIGN.md §8.5)."""
    coll, sim, index = world
    params = dataclasses.replace(default_params, ub_mode="paper")
    engine = KoiosSearch(coll, sim, params)
    q = sample_queries(coll, 1, seed=31)[0]
    res = engine.search(q)
    assert len(res.ids) <= params.k


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_search_property_random_worlds(seed):
    """Exactness on independently generated small worlds."""
    rng = np.random.default_rng(seed)
    coll = make_collection(num_sets=40, vocab_size=300, avg_size=6,
                           max_size=12, seed=seed)
    emb = make_embeddings(300, dim=16, cluster_size=3.0, seed=seed)
    sim = EmbeddingSimilarity(emb)
    params = SearchParams(k=3, alpha=0.8, chunk_size=64, verify_batch=8)
    engine = KoiosSearch(coll, sim, params)
    index = KoiosIndex.build(coll)
    q = sample_queries(coll, 1, seed=seed)[0]
    res = engine.search(q)
    ref = brute_force_topk(index, q, sim, params)
    assert _score_multiset_equal(res.lb, ref.lb[:len(res.lb)])
