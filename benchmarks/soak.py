"""Fault-injected soak harness for the serving plane (DESIGN.md §6).

Two legs, both over ONE shared :class:`ShardedCollection` resource:

* ``fault_soak`` — a sustained Zipf-skewed request trace (a small hot
  query pool drawn with Zipf weights, staggered arrivals) replayed
  through an :class:`AdmissionRouter` fleet while a seeded
  :class:`FaultPlan` crashes one replica mid-trace, injects a transient
  verifier error on another, and stalls a third.  The harness asserts
  the recovery contract end-to-end: the trace completes, no request is
  lost or duplicated, and every SERVED response (``ok`` or ``retried``)
  is bit-identical to the fault-free one-shot ``search_batch`` over the
  same collection.  Reported: p50/p99 admit->respond latency, shed
  rate, retry count, quarantine/revive counts, and recovery time
  (first quarantine -> first post-failover serve).

* ``overload`` — the same trace with deadlines tight enough that a
  slice of the requests is doomed at admission, served with
  ``shed_deadlines=True``.  Shed responses must carry ``status='shed'``
  with ZERO waves (the ``engine:shed`` instrument events are the audit
  trail that no wave tile was spent on them), while the surviving
  requests stay bit-identical.

* ``live_update`` — the crash-consistency leg (DESIGN.md §6.5): the
  first half of the trace is admitted, a replica crashes, and a live
  ``commit()`` (remove the hot top-1 set + add two) lands mid-flight
  with a snapshot on commit; the second half is admitted post-commit.
  Asserted: exactly-once rids; every served response bit-identical to
  the one-shot reference of ITS epoch (pre-commit admissions pinned to
  the old snapshot, post-commit ones reflecting the new sets);
  post-commit responses all on the new epoch; and a restore from the
  snapshot serving bit-identically to the committed head.

All legs merge their records into ``BENCH_soak.json`` (CI uploads it;
the trajectory stays comparable across PRs).

Usage:
    PYTHONPATH=src python -m benchmarks.soak [--fast] [--replicas 4]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from repro.core import KoiosSearch, SearchParams
from repro.data import sample_queries
from repro.runtime import instrument
from repro.runtime.collection import ShardedCollection
from repro.runtime.engine import AdmissionRouter, RouterPolicy
from repro.runtime.fault import FaultEvent, FaultPlan

from .common import world
from .response_time import result_hash


def zipf_trace(coll, n_requests: int, pool: int = 12, zipf_a: float = 1.3,
               seed: int = 5):
    """A skewed serving trace: ``pool`` unique queries, request i drawing
    query rank r with probability ~ 1/r^a (the stream-cache-friendly
    skew real set-search traffic shows)."""
    uniq = sample_queries(coll, pool, seed=seed)
    ranks = np.arange(1, pool + 1, dtype=np.float64)
    w = ranks ** -zipf_a
    rng = np.random.default_rng(seed)
    picks = rng.choice(pool, size=n_requests, p=w / w.sum())
    return [uniq[i] for i in picks], picks


def _mid_trace_plan(crash_replica: int = 1, crash_step: int = 2
                    ) -> FaultPlan:
    """The soak's pinned schedule: one permanent crash mid-trace, one
    revivable transient verifier error, one sub-timeout stall.  Pinned
    (not ``FaultPlan.random``) so the BENCH artifact is comparable
    across runs; the seeded generator is exercised by tests."""
    return FaultPlan([
        FaultEvent("crash", replica=crash_replica, step=crash_step),
        FaultEvent("verify_error", replica=2, step=1),
        FaultEvent("stall", replica=3, step=1, stall_s=0.005),
    ])


def run_fault_soak(dataset="opendata", replicas=4, partitions=2,
                   n_requests=48, pool=12, zipf_a=1.3, k=10, alpha=0.8,
                   stagger_ms=2.0, seed=5):
    """The failover leg: Zipf trace + mid-trace faults; asserts
    completion, exactly-once responses, and served bit-parity."""
    assert replicas >= 4, "the pinned fault plan addresses replicas 1..3"
    params = SearchParams(k=k, alpha=alpha)
    coll, sim = world(dataset)
    sc = ShardedCollection.build(coll, partitions)
    queries, picks = zipf_trace(coll, n_requests, pool=pool,
                                zipf_a=zipf_a, seed=seed)

    # fault-free one-shot reference over the SAME collection resource
    ref = KoiosSearch(None, sim, params,
                      collection=sc).search_batch(queries)

    plan = _mid_trace_plan()
    router = AdmissionRouter(None, sim, params, replicas=replicas,
                             collection=sc, policy=RouterPolicy())
    router.warmup(queries[:2])
    for eng in router.engines:      # attach faults AFTER warmup so the
        eng.fault_plan = plan       # step addresses count live traffic
        eng._step_no = 0

    t0 = time.monotonic()
    now = router.clock()
    gap = stagger_ms / 1e3
    with instrument.counting() as events:
        for i, q in enumerate(queries):
            router.submit(q, arrival=now + i * gap)
        responses = sorted(router.drain(), key=lambda r: r.rid)
    wall_s = time.monotonic() - t0

    # ---- the recovery contract ----
    rids = [r.rid for r in responses]
    assert rids == list(range(n_requests)), \
        f"lost/duplicated requests: {len(rids)} responses"   # exactly once
    served = [r for r in responses if r.served]
    for r in served:                       # bit-identical to fault-free
        assert result_hash([r.result]) == result_hash([ref[r.rid]]), \
            f"request {r.rid} diverged after {r.retries} retries"
    retried = [r for r in served if r.status == "retried"]
    assert plan.take(1, 2) == [] and any(
        e.kind == "crash" for e in plan.fired), "crash never fired"
    assert retried, "the crash evacuated no requests (trace too short?)"

    s = router.summary()
    q_times = [q["t"] for q in router.quarantine_log
               if q["reason"] != "revived"]
    recovery_s = (router._t_last_recovered - min(q_times)
                  if q_times and router._t_last_recovered else None)
    lats = sorted(r.latency_s for r in served)
    qtile = lambda q: lats[min(len(lats) - 1,          # noqa: E731
                               int(q * len(lats)))] if lats else 0.0
    return {
        "dataset": dataset, "replicas": replicas, "partitions": partitions,
        "requests": n_requests, "query_pool": pool, "zipf_a": zipf_a,
        "stagger_ms": stagger_ms,
        "unique_hot_share": float(np.mean(picks == picks.min())),
        "faults_fired": [e.kind for e in plan.fired],
        "served": len(served), "retried": len(retried),
        "retries": s["retries"], "shed": s["shed"], "failed": s["failed"],
        "shed_rate": s["shed"] / n_requests,
        "quarantines": s["quarantines"],
        "revives": sum(q["reason"] == "revived"
                       for q in router.quarantine_log),
        "recovery_s": recovery_s,
        "p50_latency_s": qtile(0.50), "p99_latency_s": qtile(0.99),
        "router_events": {k: v for k, v in events.items()
                          if k.startswith("router:")},
        "served_hash": result_hash([r.result for r in served]),
        "reference_hash": result_hash([ref[r.rid] for r in served]),
        "wall_s": wall_s,
    }


def run_overload(dataset="opendata", replicas=2, partitions=2,
                 n_requests=24, pool=8, zipf_a=1.3, k=10, alpha=0.8,
                 doom_every=3, seed=6):
    """The shedding leg: every ``doom_every``-th request carries an
    already-expired deadline; with ``shed_deadlines=True`` those respond
    ``status='shed'`` BEFORE any wave tile is spent (waves == 0, one
    ``engine:shed`` event each) and the rest stay bit-identical."""
    params = SearchParams(k=k, alpha=alpha)
    coll, sim = world(dataset)
    sc = ShardedCollection.build(coll, partitions)
    queries, _ = zipf_trace(coll, n_requests, pool=pool,
                            zipf_a=zipf_a, seed=seed)
    ref = KoiosSearch(None, sim, params,
                      collection=sc).search_batch(queries)

    router = AdmissionRouter(None, sim, params, replicas=replicas,
                             collection=sc, shed_deadlines=True)
    router.warmup(queries[:2])
    t0 = time.monotonic()
    now = router.clock()
    doomed = [i % doom_every == doom_every - 1 for i in range(n_requests)]
    with instrument.counting() as events:
        deadlines = [now - 1e-3 if d else None for d in doomed]
        responses = router.serve(queries, deadlines=deadlines)
    wall_s = time.monotonic() - t0

    assert [r.rid for r in responses] == list(range(n_requests))
    shed = [r for r in responses if r.status == "shed"]
    assert [r.rid for r in shed] == [i for i, d in enumerate(doomed) if d]
    assert all(r.waves == 0 for r in shed), \
        "a shed request occupied a wave tile"    # shed BEFORE dispatch
    assert events["engine:shed"] == len(shed)    # the instrument proof
    ok = [r for r in responses if r.status == "ok"]
    assert len(ok) + len(shed) == n_requests
    for r in ok:
        assert result_hash([r.result]) == result_hash([ref[r.rid]])

    lats = sorted(r.latency_s for r in ok)
    qtile = lambda q: lats[min(len(lats) - 1,          # noqa: E731
                               int(q * len(lats)))] if lats else 0.0
    return {
        "dataset": dataset, "replicas": replicas, "partitions": partitions,
        "requests": n_requests, "doom_every": doom_every,
        "shed": len(shed), "shed_rate": len(shed) / n_requests,
        "shed_events": int(events["engine:shed"]),
        "shed_waves_total": sum(r.waves for r in shed),
        "p50_latency_s": qtile(0.50), "p99_latency_s": qtile(0.99),
        "served_hash": result_hash([r.result for r in ok]),
        "wall_s": wall_s,
    }


def run_live_update(dataset="opendata", replicas=4, partitions=2,
                    n_requests=32, pool=10, zipf_a=1.3, k=10, alpha=0.8,
                    stagger_ms=2.0, seed=7, snapshot_dir=None):
    """The crash-consistency leg (DESIGN.md §6.5): admit half the trace,
    crash a replica, land a live ``commit()`` mid-flight (snapshotting on
    commit), admit the rest, then assert the epoch contract: exactly-once
    rids; every served response bit-identical to the one-shot reference
    of ITS epoch; post-commit admissions all on the new epoch; and a
    restore from the snapshot serving bit-identically to the live head."""
    assert replicas >= 2 and n_requests >= 8
    params = SearchParams(k=k, alpha=alpha)
    coll, sim = world(dataset)
    sc = ShardedCollection.build(coll, partitions)
    queries, picks = zipf_trace(coll, n_requests, pool=pool,
                                zipf_a=zipf_a, seed=seed)
    half = n_requests // 2

    # epoch-0 one-shot reference over the whole trace
    ref_old = KoiosSearch(None, sim, params,
                          collection=sc).search_batch(queries)

    # the update removes the top-1 set of the hottest POST-commit query,
    # so the new epoch's results provably differ from the old snapshot's
    hot_pick = int(np.bincount(picks[half:]).argmax())
    hot_rid = half + int(np.argmax(picks[half:] == hot_pick))
    victim = int(ref_old[hot_rid].ids[0])

    router = AdmissionRouter(None, sim, params, replicas=replicas,
                             collection=sc, policy=RouterPolicy())
    router.warmup(queries[:2])
    plan = FaultPlan([FaultEvent("crash", replica=1, step=2)])
    for eng in router.engines:      # one mid-trace replica kill rides
        eng.fault_plan = plan       # along with the live commit
        eng._step_no = 0

    tmpdir = snapshot_dir or tempfile.mkdtemp(prefix="koios_soak_snap_")
    sc.save(tmpdir)                             # epoch-0 baseline
    sc.on_commit(lambda s: s.save(tmpdir))      # snapshot on every commit

    t0 = time.monotonic()
    gap = stagger_ms / 1e3
    with instrument.counting() as events:
        now = router.clock()
        for i, q in enumerate(queries[:half]):
            router.submit(q, arrival=now + i * gap)
        pre = []                    # step until work is in flight/served
        while not pre:              # so the commit truly lands mid-trace
            pre.extend(router.step())

        upd = sc.begin_update()
        upd.remove_sets([victim])
        upd.add_sets([coll.get_set(1).copy(), coll.get_set(3).copy()])
        new_epoch = upd.commit()

        now = router.clock()
        for i, q in enumerate(queries[half:]):
            router.submit(q, arrival=now + i * gap)
        responses = sorted(pre + router.drain(), key=lambda r: r.rid)
    wall_s = time.monotonic() - t0

    # ---- the epoch contract ----
    rids = [r.rid for r in responses]
    assert rids == list(range(n_requests)), \
        f"lost/duplicated requests: {len(rids)} responses"   # exactly once
    assert new_epoch > 0 and sc.epoch == new_epoch

    # post-commit one-shot reference (head epoch)
    ref_new = KoiosSearch(None, sim, params,
                          collection=sc).search_batch(queries)
    served = [r for r in responses if r.served]
    for r in served:        # bit-identical to the reference of ITS epoch
        ref = ref_old if r.epoch == 0 else ref_new
        assert result_hash([r.result]) == result_hash([ref[r.rid]]), \
            f"request {r.rid} (epoch {r.epoch}) diverged"
    post = [r for r in served if r.rid >= half]
    assert post and all(r.epoch == new_epoch for r in post), \
        "a post-commit admission served against a stale epoch"
    assert not np.array_equal(ref_old[hot_rid].ids, ref_new[hot_rid].ids), \
        "the commit changed nothing the post-commit trace can observe"
    assert any(e.kind == "crash" for e in plan.fired), "crash never fired"

    # restore from the snapshot left by the commit hook: same epoch,
    # bit-identical one-shot serving vs the live committed head
    restored = ShardedCollection.restore(tmpdir)
    assert restored is not None and restored.epoch == new_epoch
    ref_restored = KoiosSearch(None, sim, params,
                               collection=restored).search_batch(queries)
    assert (result_hash(ref_restored) == result_hash(ref_new)), \
        "restore-from-snapshot diverged from the committed head"

    s = router.summary()
    lats = sorted(r.latency_s for r in served)
    qtile = lambda q: lats[min(len(lats) - 1,          # noqa: E731
                               int(q * len(lats)))] if lats else 0.0
    pre_served = [r for r in served if r.epoch == 0]
    post_served = [r for r in served if r.epoch != 0]
    return {
        "dataset": dataset, "replicas": replicas, "partitions": partitions,
        "requests": n_requests, "query_pool": pool, "zipf_a": zipf_a,
        "epoch": int(sc.epoch), "removed_set": victim, "added_sets": 2,
        "commit_shared_shards": sc._last_commit["shards_shared"],
        "commit_rebuilt_shards": sc._last_commit["shards_rebuilt"],
        "served": len(served),
        "served_old_epoch": len(pre_served),
        "served_new_epoch": len(post_served),
        "retries": s["retries"], "shed": s["shed"], "failed": s["failed"],
        "quarantines": s["quarantines"],
        "resyncs": int(events.get("engine:resync", 0)),
        "rollouts": int(events.get("router:rollout", 0)),
        "commits": int(events.get("collection:commit", 0)),
        "p50_latency_s": qtile(0.50), "p99_latency_s": qtile(0.99),
        "served_hash": result_hash([r.result for r in served]),
        "reference_hash": result_hash(
            [(ref_old if r.epoch == 0 else ref_new)[r.rid] for r in served]),
        "restored_hash_matches": True,
        "snapshot_dir": tmpdir,
        "wall_s": wall_s,
    }


def write_bench_json(record: dict, path: str, mode: str) -> None:
    """BENCH_soak.json — same merge-under-``records[mode]`` layout as
    the response-time artifact, so every leg's trajectory stays
    comparable across PRs."""
    if not path:
        return
    doc = {"benchmark": "soak", "records": {}}
    try:
        with open(path) as f:
            prev = json.load(f)
        if "records" in prev:
            doc["records"] = prev["records"]
    except (OSError, ValueError):
        pass
    doc["records"][mode] = record
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path} (mode={mode}, "
          f"{len(doc['records'])} records)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="opendata")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--fast", action="store_true",
                    help="trim the trace for CI smoke (~20s)")
    ap.add_argument("--json", default="BENCH_soak.json")
    args = ap.parse_args(argv)
    n = 24 if args.fast else args.requests

    print("leg,requests,p50_s,p99_s,shed_rate,retries,quarantines,"
          "recovery_s,bit_identical")
    r = run_fault_soak(args.dataset, replicas=args.replicas,
                       partitions=args.partitions, n_requests=n)
    ok = r["served_hash"] == r["reference_hash"]
    rec = f"{r['recovery_s']:.4f}" if r["recovery_s"] is not None else "-"
    print(f"fault_soak,{r['requests']},{r['p50_latency_s']:.4f},"
          f"{r['p99_latency_s']:.4f},{r['shed_rate']:.2f},{r['retries']},"
          f"{r['quarantines']},{rec},{ok}")
    write_bench_json(r, args.json, "fault_soak")

    o = run_overload(args.dataset, partitions=args.partitions,
                     n_requests=max(n // 2, 12))
    print(f"overload,{o['requests']},{o['p50_latency_s']:.4f},"
          f"{o['p99_latency_s']:.4f},{o['shed_rate']:.2f},0,0,-,True")
    write_bench_json(o, args.json, "overload")

    u = run_live_update(args.dataset, replicas=args.replicas,
                        partitions=args.partitions,
                        n_requests=max(2 * (n // 3), 16))
    ok = u["served_hash"] == u["reference_hash"]
    print(f"live_update,{u['requests']},{u['p50_latency_s']:.4f},"
          f"{u['p99_latency_s']:.4f},0.00,{u['retries']},"
          f"{u['quarantines']},-,{ok}")
    print(f"[live_update] epoch={u['epoch']} "
          f"shards shared={u['commit_shared_shards']} "
          f"rebuilt={u['commit_rebuilt_shards']} "
          f"served old/new={u['served_old_epoch']}/{u['served_new_epoch']} "
          f"resyncs={u['resyncs']} restored_ok={u['restored_hash_matches']}")
    write_bench_json(u, args.json, "live_update")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
