"""Pytree checkpointing: msgpack (+ optional zstd), atomic, async-capable.

Layout-agnostic: arrays are serialized host-side (device_get) with dtype
(incl. bfloat16 via ml_dtypes) and shape; restore returns numpy arrays that
``jax.device_put``/``NamedSharding`` reshard onto whatever mesh the restart
uses — this is what makes elastic re-mesh restarts work (runtime/fault.py):
a checkpoint written on a (2,16,16) mesh restores onto any other mesh.

``zstandard`` is an optional dependency (requirements-dev.txt): when absent,
checkpoints are written as raw msgpack.  ``restore`` sniffs the zstd frame
magic, so either codec restores on any host that can decode it; the codec
used is recorded in the checkpoint metadata by ``CheckpointManager``."""
from __future__ import annotations

import io
import os
import tempfile
import threading
from concurrent.futures import ThreadPoolExecutor, Future
from typing import Any, Optional

import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover — exercised in the seed environment
    zstd = None

import jax

# First bytes of every zstd frame (RFC 8878) — msgpack maps never start
# with this, so the on-disk codec is sniffable without a side channel.
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def default_codec() -> str:
    """Codec ``save`` will use on this host (recorded in ckpt metadata)."""
    return "zstd" if zstd is not None else "raw"

try:
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    _BF16 = None


def _encode_dtype(dt: np.dtype) -> str:
    return dt.name


def _decode_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        assert _BF16 is not None, "bfloat16 checkpoint needs ml_dtypes"
        return _BF16
    return np.dtype(name)


def _pack(obj):
    if isinstance(obj, dict):
        return {"t": "d", "v": {k: _pack(v) for k, v in obj.items()}}
    if isinstance(obj, (list, tuple)):
        return {"t": "l" if isinstance(obj, list) else "tu",
                "v": [_pack(v) for v in obj]}
    if obj is None:
        return {"t": "n"}
    if isinstance(obj, (int, float, str, bool)):
        return {"t": "s", "v": obj}
    arr = np.asarray(obj)
    return {"t": "a", "dtype": _encode_dtype(arr.dtype),
            "shape": list(arr.shape), "data": arr.tobytes()}


def _unpack(obj):
    t = obj["t"]
    if t == "d":
        return {k: _unpack(v) for k, v in obj["v"].items()}
    if t == "l":
        return [_unpack(v) for v in obj["v"]]
    if t == "tu":
        return tuple(_unpack(v) for v in obj["v"])
    if t == "n":
        return None
    if t == "s":
        return obj["v"]
    dt = _decode_dtype(obj["dtype"])
    return np.frombuffer(obj["data"], dtype=dt).reshape(obj["shape"])


def _to_host(x):
    if isinstance(x, (str, bool, int, float)) or x is None:
        return x
    return np.asarray(jax.device_get(x))


def save(path: str, tree: Any, *, level: int = 3) -> None:
    """Atomic synchronous save (tmp file + rename).

    Compresses with zstd when available, else writes raw msgpack."""
    host_tree = jax.tree_util.tree_map(_to_host, tree)
    payload = msgpack.packb(_pack(host_tree), use_bin_type=True)
    if zstd is not None:
        comp = zstd.ZstdCompressor(level=level).compress(payload)
    else:
        comp = payload
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(comp)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore(path: str) -> Any:
    with open(path, "rb") as f:
        comp = f.read()
    if comp[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise RuntimeError(
                f"{path} is zstd-compressed but zstandard is not installed "
                "(pip install zstandard, see requirements-dev.txt)")
        payload = zstd.ZstdDecompressor().decompress(comp)
    else:
        payload = comp
    return _unpack(msgpack.unpackb(payload, raw=False))


class AsyncSaver:
    """Snapshot on the caller thread (cheap device_get), write off-thread —
    checkpointing off the training critical path (DESIGN.md §5)."""

    def __init__(self):
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._last: Optional[Future] = None

    def save(self, path: str, tree: Any) -> Future:
        self.wait()
        host_tree = jax.tree_util.tree_map(_to_host, tree)
        self._last = self._pool.submit(save, path, host_tree)
        return self._last

    def wait(self):
        if self._last is not None:
            self._last.result()
            self._last = None
