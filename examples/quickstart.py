"""Quickstart: top-k semantic overlap search in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import EmbeddingSimilarity, KoiosSearch, SearchParams
from repro.data import make_collection, make_embeddings, sample_queries

# 1. A repository of token sets (generate a synthetic one here; any CSR
#    SetCollection works — e.g. the distinct values of your table columns).
coll = make_collection(num_sets=500, vocab_size=4000, avg_size=10,
                       max_size=40, seed=0)

# 2. A similarity provider: cosine over an embedding table.  Swap in your
#    own vectors (FastText, a trained tower, ...) — KOIOS only needs
#    sim(x, x) = 1 and symmetry (paper Def. 1).
table = make_embeddings(coll.vocab_size, dim=64, seed=0)
sim = EmbeddingSimilarity(table)

# 3. Search.  alpha is the element-similarity threshold, k the result size.
engine = KoiosSearch(coll, sim, SearchParams(k=5, alpha=0.8))
query = sample_queries(coll, 1, seed=42)[0]
result = engine.search(query)

print(f"query |Q|={len(query)}: {query[:8]}...")
for rank, (sid, score) in enumerate(zip(result.ids, result.lb), 1):
    overlap = len(np.intersect1d(query, coll.get_set(int(sid))))
    print(f"  #{rank} set {sid:4d}  SO={score:6.2f}  "
          f"(vanilla overlap {overlap})")
st = result.stats
print(f"\ncandidates={st.candidates}  pruned_refinement="
      f"{st.pruned_refinement}  verified={st.exact_matches}  "
      f"no_em={st.pruned_no_em}")
print("=> the paper's claim in action: only "
      f"{100*st.exact_matches/max(st.candidates,1):.1f}% of candidates "
      "needed an exact graph matching")

# 4. Batched serving: many queries through ONE fused pipeline — a single
#    stacked similarity sweep and a shared cross-query verification queue.
#    Results are bit-identical to per-query search(); per-query latency
#    drops >2x at batch size 8 (benchmarks/response_time.py --batched).
queries = sample_queries(coll, 4, seed=43)
for i, res in enumerate(engine.search_batch(queries)):
    print(f"batched query {i} (|Q|={len(queries[i])}): "
          f"top ids={res.ids[:3].tolist()} "
          f"scores={[round(float(s), 2) for s in res.lb[:3]]}")
