"""Paper Table III: response time + memory, KOIOS vs Baseline/Baseline+.

Also covers the SilkMoth comparison mode (--sim ngram): the same engine
with character n-gram Jaccard similarity (KOIOS is similarity-agnostic —
§VIII-B).

Batched-serving A/B (``--batched`` / ``--per-query``): times the fused
multi-query pipeline (``search_partition_batch``) against the per-query
loop on the same query batch, asserting identical top-k results:

    PYTHONPATH=src python -m benchmarks.response_time --batched

Scale-out A/B (``--partitions N --overlap``): times the overlapped
partition scheduler (async refinement dispatch, global verify queue,
bidirectional theta_lb feedback) against the sequential running-max
partition loop, asserting bit-identical results:

    PYTHONPATH=src python -m benchmarks.response_time --partitions 4 --overlap

Fused-wave A/B (``--fused``): times the on-device wave schedule (one
device program per partition wave — refinement chunk scans + compaction +
the first R verification rounds fused, DESIGN.md §3) against the
host-driven overlap schedule, counting host<->device dispatches/transfers
with ``repro.runtime.instrument`` and asserting bit-identical results:

    PYTHONPATH=src python -m benchmarks.response_time --fused --partitions 4

Every A/B invocation also writes ``BENCH_response_time.json`` (per-mode
latencies + a hash of the results) so CI accumulates the perf trajectory
as an artifact; ``--json ''`` disables.
"""
from __future__ import annotations

import argparse
import hashlib
import json

import numpy as np

from repro.core import (NGramJaccardSimilarity, SearchParams,
                        baseline_plus_topk, baseline_topk, search_partition,
                        search_partition_batch)
from repro.data import sample_queries

from .common import index_for, memory_footprint_bytes, timed, world


def _ngram_incidence(vocab_size: int, dim: int = 512, seed: int = 0):
    """Hashed 3-gram incidence stand-in (tokens are synthetic ids; we hash
    pseudo-spellings)."""
    rng = np.random.default_rng(seed)
    inc = np.zeros((vocab_size, dim), np.float32)
    for t in range(vocab_size):
        g = rng.integers(0, dim, size=6)      # ~6 3-grams per token
        inc[t, g] = 1.0
    return inc


def run(datasets=("dblp", "opendata", "twitter", "wdc"), n_queries=2,
        k=10, alpha=0.8, sim_kind="cosine", include_baseline=True):
    rows = []
    params = SearchParams(k=k, alpha=alpha)
    for ds in datasets:
        coll, sim = world(ds)
        if sim_kind == "ngram":
            sim = NGramJaccardSimilarity(_ngram_incidence(coll.vocab_size))
        index = index_for(ds)
        queries = sample_queries(coll, n_queries, seed=11)
        # warm the jit caches (the paper's timings exclude setup; pow2
        # padding makes later queries reuse these compilations)
        if queries:
            search_partition(index, queries[0], sim, params)
            if include_baseline:
                baseline_topk(index, queries[0], sim, params)
        tk = tb = tbp = 0.0
        match_k = match_b = 0
        for q in queries:
            rk, dt = timed(search_partition, index, q, sim, params)
            tk += dt
            match_k += rk.stats.exact_matches
            if include_baseline:
                rb, dt = timed(baseline_topk, index, q, sim, params)
                tb += dt
                match_b += rb.stats.exact_matches
                rbp, dt = timed(baseline_plus_topk, index, q, sim, params)
                tbp += dt
                # sanity: identical score multisets
                assert np.allclose(np.sort(rk.lb), np.sort(rb.lb), atol=1e-3)
        n = max(len(queries), 1)
        mem = memory_footprint_bytes(ds, int(np.mean(
            [len(q) for q in queries])) if queries else 1)
        rows.append({
            "dataset": ds, "sim": sim_kind, "queries": n,
            "koios_s": tk / n,
            "baseline_s": tb / n if include_baseline else None,
            "baseline_plus_s": tbp / n if include_baseline else None,
            "speedup": (tb / tk) if include_baseline and tk else None,
            "em_koios": match_k / n,
            "em_baseline": match_b / n if include_baseline else None,
            "mem_mb": mem["total"] / 1e6,
        })
    return rows


def run_ab(dataset="opendata", batch_size=8, k=10, alpha=0.8,
           verifier="hungarian", repeats=3):
    """Batched vs per-query A/B on one query batch; identical-results check.

    Both paths are warmed (jit caches), then each is timed ``repeats``
    times over the same ``batch_size`` queries; reports mean seconds per
    query and the batched-path speedup.
    """
    params = SearchParams(k=k, alpha=alpha, verifier=verifier)
    _, sim = world(dataset)
    index = index_for(dataset)
    queries = sample_queries(index.coll, batch_size, seed=11)
    zeros = [0.0] * len(queries)

    def per_query():
        return [search_partition(index, q, sim, params) for q in queries]

    def batched():
        return search_partition_batch(index, queries, sim, params, zeros)

    r_pq, _ = timed(per_query)       # warm both paths before timing
    r_b, _ = timed(batched)
    for a, b in zip(r_pq, r_b):
        assert np.array_equal(a.ids, b.ids) and np.array_equal(a.lb, b.lb), \
            "batched path diverged from per-query results"

    t_pq = min(timed(per_query)[1] for _ in range(repeats))
    t_b = min(timed(batched)[1] for _ in range(repeats))
    n = len(queries)
    return {
        "dataset": dataset, "batch_size": n, "verifier": verifier,
        "per_query_s": t_pq / n, "batched_s": t_b / n,
        "speedup": t_pq / t_b if t_b else float("inf"),
        "result_hash": result_hash(r_b),
        "identical_topk": True,
    }


def run_partition_ab(dataset="opendata", partitions=4, batch_size=8, k=10,
                     alpha=0.8, verifier="hungarian", repeats=3):
    """Overlapped scheduler vs sequential partition loop at P partitions.

    Both arms run the same engine (same plan decomposition, same shared
    verifier pool); the A/B isolates the scheduler's drive order —
    overlapped refinement dispatch + the global cross-partition queue +
    bidirectional theta_lb feedback vs the pre-scheduler running-max host
    loop.  Results are asserted bit-identical; reports mean seconds per
    query and the overlap speedup.
    """
    from repro.core import KoiosSearch

    params = SearchParams(k=k, alpha=alpha, verifier=verifier)
    coll, sim = world(dataset)
    engine = KoiosSearch(coll, sim, params, partitions=partitions)
    queries = sample_queries(coll, batch_size, seed=11)

    def sequential():
        return engine.search_batch(queries, schedule="sequential")

    def overlap():
        return engine.search_batch(queries, schedule="overlap")

    r_seq, _ = timed(sequential)     # warm both paths before timing
    r_ovl, _ = timed(overlap)
    st = engine.scheduler_stats
    for a, b in zip(r_seq, r_ovl):
        assert np.array_equal(a.ids, b.ids) and np.array_equal(a.lb, b.lb), \
            "overlapped schedule diverged from the sequential partition loop"

    t_seq = min(timed(sequential)[1] for _ in range(repeats))
    t_ovl = min(timed(overlap)[1] for _ in range(repeats))
    n = len(queries)
    return {
        "dataset": dataset, "partitions": partitions, "batch_size": n,
        "verifier": verifier,
        "sequential_s": t_seq / n, "overlap_s": t_ovl / n,
        "speedup": t_seq / t_ovl if t_ovl else float("inf"),
        "bound_raises": st.bound_raises,
        "backward_raises": st.backward_raises,
        "result_hash": result_hash(r_ovl),
        "identical_topk": True,
    }


def result_hash(results) -> str:
    """Stable digest of a list of SearchResults (ids + score bits)."""
    h = hashlib.sha256()
    for r in results:
        h.update(np.ascontiguousarray(r.ids).tobytes())
        h.update(np.ascontiguousarray(r.lb).tobytes())
    return h.hexdigest()[:16]


def run_fused_ab(dataset="opendata", partitions=4, batch_size=8, k=10,
                 alpha=0.8, verifier="hungarian", repeats=7):
    """Fused on-device wave schedule vs host-driven overlap at P partitions.

    Both arms run the identical plan decomposition; the A/B isolates what
    the wave program eliminates — per-tile refinement dispatch +
    materialization and the first R rounds' pairwise/solver round-trips.
    Host<->device dispatches and transfers are counted via
    ``repro.runtime.instrument``; results are asserted bit-identical."""
    import jax

    from repro.core import KoiosSearch
    from repro.runtime import instrument

    fused_mode = "auto" if jax.default_backend() == "tpu" else "interpret"
    params = SearchParams(k=k, alpha=alpha, verifier=verifier,
                          fused=fused_mode)
    coll, sim = world(dataset)
    engine = KoiosSearch(coll, sim, params, partitions=partitions)
    queries = sample_queries(coll, batch_size, seed=11)

    def overlap():
        return engine.search_batch(queries, schedule="overlap")

    def fused():
        return engine.search_batch(queries, schedule="fused")

    r_ovl, _ = timed(overlap)        # warm both paths before timing
    r_fus, _ = timed(fused)
    assert engine.scheduler_stats.schedule == "fused", \
        "fused schedule unavailable (provider or backend gate)"
    for a, b in zip(r_ovl, r_fus):
        assert np.array_equal(a.ids, b.ids) and np.array_equal(a.lb, b.lb), \
            "fused wave schedule diverged from the overlap schedule"

    counts = {}
    for name, fn in (("overlap", overlap), ("fused", fused)):
        with instrument.counting() as c:
            fn()
        counts[name] = instrument.totals(c)
    t_ovl = min(timed(overlap)[1] for _ in range(repeats))
    t_fus = min(timed(fused)[1] for _ in range(repeats))
    n = len(queries)
    st = engine.scheduler_stats
    return {
        "dataset": dataset, "partitions": partitions, "batch_size": n,
        "verifier": verifier,
        "overlap_s": t_ovl / n, "fused_s": t_fus / n,
        "speedup": t_ovl / t_fus if t_fus else float("inf"),
        "overlap_transfers": counts["overlap"]["total"],
        "fused_transfers": counts["fused"]["total"],
        "waves": st.waves, "device_rounds": st.device_rounds,
        "result_hash": result_hash(r_fus),
        "identical_topk": True,
    }


def write_bench_json(payload: dict, path: str) -> None:
    """BENCH_response_time.json — the perf-trajectory artifact CI uploads."""
    if not path:
        return
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--batched", action="store_true",
                      help="A/B the fused multi-query path (headline row)")
    mode.add_argument("--per-query", action="store_true",
                      help="A/B with the per-query loop as the headline row")
    mode.add_argument("--overlap", action="store_true",
                      help="A/B the overlapped partition scheduler vs the "
                           "sequential partition loop (use --partitions)")
    mode.add_argument("--fused", action="store_true",
                      help="A/B the fused on-device wave schedule vs the "
                           "overlap schedule (use --partitions; interpret "
                           "mode off-TPU)")
    ap.add_argument("--dataset", default=None,
                    help="restrict to one dataset (A/B default: opendata; "
                         "table mode default: all four)")
    ap.add_argument("--batch-size", type=int, default=8,
                    help="A/B modes only")
    ap.add_argument("--partitions", type=int, default=4,
                    help="--overlap A/B only: repository partition count")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--verifier", default="hungarian",
                    choices=["hungarian", "auction", "hybrid"],
                    help="A/B modes only")
    ap.add_argument("--json", default="BENCH_response_time.json",
                    help="perf-artifact path for A/B modes ('' disables)")
    args = ap.parse_args(argv)

    if args.fused:
        r = run_fused_ab(args.dataset or "opendata", args.partitions,
                         args.batch_size, k=args.k,
                         verifier=args.verifier)
        print("dataset,schedule,partitions,batch_size,"
              "mean_latency_per_query_s,speedup_vs_overlap,"
              "transfers,waves,device_rounds,result_hash,identical_topk")
        for name, lat, sp, tr in (
                ("fused", r["fused_s"], r["speedup"],
                 r["fused_transfers"]),
                ("overlap", r["overlap_s"], 1.0, r["overlap_transfers"])):
            print(f"{r['dataset']},{name},{r['partitions']},"
                  f"{r['batch_size']},{lat:.4f},{sp:.2f},{tr},"
                  f"{r['waves']},{r['device_rounds']},"
                  f"{r['result_hash']},{r['identical_topk']}")
        write_bench_json({
            "benchmark": "response_time", "mode": "fused_ab",
            "modes": {
                "fused": {"mean_latency_per_query_s": r["fused_s"],
                          "transfers": r["fused_transfers"]},
                "overlap": {"mean_latency_per_query_s": r["overlap_s"],
                            "transfers": r["overlap_transfers"]},
            },
            "speedup": r["speedup"], "result_hash": r["result_hash"],
            "dataset": r["dataset"], "partitions": r["partitions"],
            "batch_size": r["batch_size"], "verifier": r["verifier"],
        }, args.json)
        assert r["fused_transfers"] < r["overlap_transfers"], \
            "fused wave must reduce host<->device transfers"
        return 0

    if args.overlap:
        r = run_partition_ab(args.dataset or "opendata", args.partitions,
                             args.batch_size, k=args.k,
                             verifier=args.verifier)
        print("dataset,schedule,partitions,batch_size,"
              "mean_latency_per_query_s,speedup_vs_sequential,"
              "bound_raises,backward_raises,identical_topk")
        for name, lat, sp in (("overlap", r["overlap_s"], r["speedup"]),
                              ("sequential", r["sequential_s"], 1.0)):
            print(f"{r['dataset']},{name},{r['partitions']},"
                  f"{r['batch_size']},{lat:.4f},{sp:.2f},"
                  f"{r['bound_raises']},{r['backward_raises']},"
                  f"{r['identical_topk']}")
        write_bench_json({
            "benchmark": "response_time", "mode": "partition_ab",
            "modes": {
                "overlap": {"mean_latency_per_query_s": r["overlap_s"]},
                "sequential": {
                    "mean_latency_per_query_s": r["sequential_s"]},
            },
            "speedup": r["speedup"], "result_hash": r["result_hash"],
            "dataset": r["dataset"], "partitions": r["partitions"],
            "batch_size": r["batch_size"], "verifier": r["verifier"],
        }, args.json)
        return 0

    if args.batched or args.per_query:
        r = run_ab(args.dataset or "opendata", args.batch_size, k=args.k,
                   verifier=args.verifier)
        print("dataset,mode,batch_size,mean_latency_per_query_s,"
              "speedup_vs_per_query,identical_topk")
        rows = [("batched", r["batched_s"], r["speedup"]),
                ("per-query", r["per_query_s"], 1.0)]
        if args.per_query:
            rows.reverse()
        for mode_name, lat, sp in rows:
            print(f"{r['dataset']},{mode_name},{r['batch_size']},"
                  f"{lat:.4f},{sp:.2f},{r['identical_topk']}")
        write_bench_json({
            "benchmark": "response_time", "mode": "batched_ab",
            "modes": {
                "batched": {"mean_latency_per_query_s": r["batched_s"]},
                "per_query": {
                    "mean_latency_per_query_s": r["per_query_s"]},
            },
            "speedup": r["speedup"], "result_hash": r["result_hash"],
            "dataset": r["dataset"], "batch_size": r["batch_size"],
            "verifier": r["verifier"],
        }, args.json)
        return 0

    table_kw = {"k": args.k}
    if args.dataset:
        table_kw["datasets"] = (args.dataset,)
    print("dataset,sim,koios_s,baseline_s,baseline+_s,speedup,"
          "em_koios,em_baseline,mem_mb")
    for r in run(**table_kw):
        print(f"{r['dataset']},{r['sim']},{r['koios_s']:.2f},"
              f"{r['baseline_s']:.2f},{r['baseline_plus_s']:.2f},"
              f"{r['speedup']:.1f},{r['em_koios']:.0f},"
              f"{r['em_baseline']:.0f},{r['mem_mb']:.1f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
