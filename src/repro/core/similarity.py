"""Element-similarity providers (the paper's user-defined ``sim``).

KOIOS only requires ``sim`` to be symmetric, 1 for identical elements and in
[0, 1] otherwise (Def. 1).  The paper's experiments use cosine similarity of
FastText embeddings; its SilkMoth comparison uses Jaccard of 3-grams.  We
provide both:

* :class:`EmbeddingSimilarity` — cosine over an embedding table.  The table
  can be a frozen random-projection table (paper-faithful stand-in for
  FastText, see ``repro.data.embeddings``) or rows produced by any of the
  framework's model towers.
* :class:`NGramJaccardSimilarity` — character n-gram Jaccard, represented as
  binary n-gram incidence vectors so that the *same* blocked-matmul machinery
  drives the token stream (Jaccard(a,b) = |A∩B| / (|A|+|B|-|A∩B|), and |A∩B|
  of binary vectors is a dot product — MXU-friendly).

Both expose the interface the search engine needs:
  - ``pairwise(q_ids, t_ids)``        -> dense sim block
  - ``query_vs_vocab_block(q_ids, lo, hi)`` -> sim block against vocab slice

Identity pairs are clamped to exactly 1.0 (Def. 1) which also implements the
paper's out-of-vocabulary rule: identical tokens count with similarity one
even when their vectors are degenerate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _l2_normalize(x: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, eps)


@functools.partial(jax.jit, static_argnames=())
def _cosine_block(qv: jnp.ndarray, tv: jnp.ndarray) -> jnp.ndarray:
    s = _l2_normalize(qv) @ _l2_normalize(tv).T
    return jnp.clip(s, 0.0, 1.0)


@jax.jit
def _jaccard_block(qv: jnp.ndarray, tv: jnp.ndarray) -> jnp.ndarray:
    inter = qv @ tv.T
    qa = jnp.sum(qv, axis=-1, keepdims=True)
    tb = jnp.sum(tv, axis=-1, keepdims=True)
    union = qa + tb.T - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)


class EmbeddingSimilarity:
    """Cosine similarity over a (vocab, dim) embedding table."""

    name = "cosine"

    def __init__(self, table: np.ndarray):
        assert table.ndim == 2
        self.table = jnp.asarray(table, dtype=jnp.float32)
        self.vocab_size, self.dim = table.shape

    @property
    def normalized_table(self) -> jnp.ndarray:
        """Row-L2-normalized table, computed once and kept device-resident
        (the fused wave program and the kernel stream path gather from it
        every call).  Row-wise normalization is subset-invariant, so
        entries gathered from this table match the per-call
        ``_cosine_block`` normalization bit for bit."""
        t = getattr(self, "_table_n", None)
        if t is None:
            t = _l2_normalize(self.table)
            self._table_n = t
        return t

    def _fix_identity(self, s: jnp.ndarray, q_ids, t_ids) -> jnp.ndarray:
        same = q_ids[:, None] == t_ids[None, :]
        return jnp.where(same, 1.0, s)

    def pairwise(self, q_ids: np.ndarray, t_ids: np.ndarray) -> jnp.ndarray:
        q_ids = jnp.asarray(q_ids)
        t_ids = jnp.asarray(t_ids)
        s = _cosine_block(self.table[q_ids], self.table[t_ids])
        return self._fix_identity(s, q_ids, t_ids)

    def query_vs_vocab_block(self, q_ids: np.ndarray, lo: int, hi: int) -> jnp.ndarray:
        q_ids = jnp.asarray(q_ids)
        t_ids = jnp.arange(lo, hi)
        s = _cosine_block(self.table[q_ids], self.table[lo:hi])
        return self._fix_identity(s, q_ids, t_ids)


def normalized_table_for(provider) -> jnp.ndarray:
    """Cached device-resident normalized table of any cosine table
    provider (the fused wave program and the kernel stream path share
    this).  :class:`EmbeddingSimilarity` subclasses expose the cached
    property directly; duck-typed providers with a ``.table`` get the
    same one-time normalize-and-cache treatment here."""
    t = getattr(provider, "normalized_table", None)
    if t is not None:
        return t
    t = getattr(provider, "_table_n", None)
    if t is None:
        from ..runtime import instrument
        instrument.record("h2d:table_upload")
        t = _l2_normalize(jnp.asarray(provider.table, jnp.float32))
        provider._table_n = t
    return t


class NGramJaccardSimilarity:
    """Jaccard of character n-grams via binary incidence vectors.

    ``incidence`` is a (vocab, n_gram_dim) {0,1} float matrix (hashed n-gram
    space).  Exact for n-gram universes up to ``n_gram_dim`` without hash
    collisions; with hashing it remains symmetric and in [0,1] (Def. 1 only
    needs those properties plus identity=1, which we clamp).
    """

    name = "ngram_jaccard"

    def __init__(self, incidence: np.ndarray):
        assert incidence.ndim == 2
        self.table = jnp.asarray(incidence, dtype=jnp.float32)
        self.vocab_size, self.dim = incidence.shape

    def _fix_identity(self, s, q_ids, t_ids):
        same = q_ids[:, None] == t_ids[None, :]
        return jnp.where(same, 1.0, jnp.clip(s, 0.0, 1.0))

    def pairwise(self, q_ids, t_ids):
        q_ids = jnp.asarray(q_ids)
        t_ids = jnp.asarray(t_ids)
        s = _jaccard_block(self.table[q_ids], self.table[t_ids])
        return self._fix_identity(s, q_ids, t_ids)

    def query_vs_vocab_block(self, q_ids, lo: int, hi: int):
        q_ids = jnp.asarray(q_ids)
        t_ids = jnp.arange(lo, hi)
        s = _jaccard_block(self.table[q_ids], self.table[lo:hi])
        return self._fix_identity(s, q_ids, t_ids)
