"""Batched auction algorithm — the TPU-native exact verifier (DESIGN.md §2).

The paper verifies candidates with the (sequential) Hungarian algorithm on a
CPU thread pool and early-terminates a matching when the node-label sum (a
*dual* upper bound) drops below theta_lb (Lemma 8).  On TPU we use Bertsekas'
auction algorithm instead:

  * every bidding round is dense, branch-free linear algebra (profit matrix,
    per-row top-2, per-column max) — VPU/MXU work, `vmap`-able over a batch
    of candidate sets;
  * the auction maintains *prices* (dual variables); the dual objective
        D = sum_j p_j + sum_i max(0, max_j (w_ij - p_j))
    upper-bounds SO at every round (weak duality).  Lemma 8's early
    termination falls out: abort the moment D < theta_lb;
  * with eps-scaling down to eps_min, the final assignment's score P
    satisfies  P >= SO - nq * eps_min,  so [P, min(D, P + nq*eps_min)] is a
    valid (lb, ub) bracket for SO.  The search treats verification results as
    brackets; brackets that straddle a decision threshold are re-verified
    exactly (hungarian) — so the search stays exact.

Matching is *optional* (Def. 1): a virtual null object with value 0 and
permanent price 0 absorbs persons whose best profit is <= 0.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

_NEG = jnp.float32(-1e30)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["lb", "ub", "assign", "early_stopped", "rounds"],
    meta_fields=[])
@dataclasses.dataclass(frozen=True)
class AuctionResult:
    lb: jnp.ndarray          # (B,) primal score (== SO up to nq*eps)
    ub: jnp.ndarray          # (B,) dual bound   (>= SO, always valid)
    assign: jnp.ndarray      # (B, N) column per row; -1 unmatched/null
    early_stopped: jnp.ndarray  # (B,) bool — aborted by theta_lb (Lemma 8)
    rounds: jnp.ndarray      # (B,) int32 bidding rounds executed


def _auction_single(w, nq, nc, eps_schedule, theta_lb, max_rounds,
                    use_kernel: bool = False):
    """One padded weight matrix (N, M); logical sizes (nq, nc) <= (N, M).

    The problem is embedded in the K x K zero-padded square matrix
    (K = max(N, M)) but only the nq *logical* rows ever bid — the zero
    padding rows have nothing to win and forcing them through the bidding
    (the historical square/perfect formulation) costs O(K - nq) extra
    rounds per phase, the auction analogue of the square-padding cost the
    nq-bounded Hungarian augmentation already eliminated
    (``hungarian._solve_square_min(n_aug=nq)``).  Soundness of the
    nq-row form:

      * lb is the score of a feasible (optional) matching, so lb <= SO
        always;
      * the dual objective
            D = sum_j p_j + sum_i max(0, max_j (w_ij - p_j))
        upper-bounds SO for any nonneg prices (weak duality) — this is the
        Lemma-8 early-termination bound, unchanged;
      * at phase end every assigned row i satisfies eps-CS
        (profit_i >= best_i - eps).  Summing eps-CS against an optimal
        assignment sigma* gives
            SO <= lb + nq*eps + sum_{j in sigma*\\A} p_j
               <= lb + nq*eps + leftover,
        with leftover = the total price of columns left unassigned.  The
        phase-transition rules below (zero unmatched columns' prices,
        release eps-CS violators *with their column zeroed*, to a
        fixpoint) maintain the invariant that a positively-priced column
        is always assigned — within a phase a bid can only transfer a
        column, never abandon it, and prices only rise — so at
        convergence leftover == 0 and the bracket is nq-tight:
            ub - lb <= nq * eps_final
        (the contract tests/test_matching.py guards against Hungarian).
        ``leftover`` stays in the ub formula as a defensive term; if the
        invariant were ever broken the bracket would widen, never lie.
    """
    N, M = w.shape
    K = max(N, M)                    # square, zero-padded
    rows = jnp.arange(K)
    cols = jnp.arange(K)
    row_valid = rows < nq
    col_valid = cols < nc
    wm = jnp.zeros((K, K), dtype=jnp.float32)
    wm = wm.at[:N, :M].set(w.astype(jnp.float32))
    wm = jnp.where(row_valid[:, None] & col_valid[None, :],
                   jnp.maximum(wm, 0.0), 0.0)

    def dual_bound(prices):
        # D >= SO for any nonneg prices (weak duality); all entries finite.
        profits = wm - prices[None, :]
        best = jnp.max(profits, axis=1)
        return jnp.sum(prices) + jnp.sum(jnp.maximum(best, 0.0))

    def _cols_taken(assign):
        hit = jnp.zeros((K,), jnp.int32).at[jnp.clip(assign, 0, K - 1)].max(
            (assign >= 0).astype(jnp.int32))
        return hit > 0

    def phase(carry, eps):
        prev_assign, prev_eps, prices, ub_best, early, total_rounds = carry
        # Phase transition, in place of the classical reset-and-rebid:
        #   1. stale-price hygiene — a column that ended the previous phase
        #      unmatched keeps no price, and matched columns are rebated the
        #      previous eps (winning bids overshoot the competitive level by
        #      up to eps; carrying the overshoot strands columns that then
        #      attract no bids at smaller eps);
        #   2. the previous assignment is KEPT and rows whose eps-CS is
        #      violated at the new eps are released *with their column's
        #      price zeroed*, iterated to a fixpoint (zeroing a column can
        #      invalidate another row's eps-CS).  Resetting the assignment
        #      while keeping prices makes the nq-row form oscillate between
        #      phases, and releasing without zeroing strands price mass on
        #      abandoned columns (the historical square form hid both by
        #      having the zero rows re-absorb every column).
        # Both steps are sound for any nonneg prices: the dual bound is
        # price-history-free, and eps-CS is re-established here and then
        # preserved within the phase (alternative profits only fall as
        # prices rise; a held column's price is constant while held; a
        # column is only freed by eviction, which re-awards it).  The
        # invariant they buy: at phase end every positively-priced column
        # is assigned, so the optimality gap of the final assignment is
        # nq*eps with NO unassigned-price leftover.
        prices = jnp.where(_cols_taken(prev_assign),
                           jnp.maximum(prices - prev_eps, 0.0), 0.0)

        def rel_body(s):
            assign, prices, _ = s
            profits = wm - prices[None, :]
            best = jnp.max(profits, axis=1)
            held = jnp.clip(assign, 0, K - 1)
            viol = (assign >= 0) & (profits[rows, held] < best - eps)
            freed = jnp.zeros((K,), bool).at[held].max(viol)
            prices = jnp.where(freed, 0.0, prices)
            assign = jnp.where(viol, jnp.int32(-1), assign)
            return assign, prices, jnp.any(viol)

        assign0, prices, _ = jax.lax.while_loop(
            lambda s: s[2], rel_body,
            (prev_assign, prices, jnp.bool_(True)))

        def cond(s):
            assign, prices, ub_best, early, r = s
            unfinished = jnp.any((assign == -1) & row_valid)
            return unfinished & (~early) & (r < max_rounds)

        def body(s):
            assign, prices, ub_best, early, r = s
            if use_kernel:
                # fused subtract + per-row top-2 (kernels/auction_round.py):
                # the (K, K) profit matrix never materializes in HBM.  Same
                # first-index tie-breaking as the inline pass below.
                from ...kernels import ops as _kops
                w1, w2, jstar = _kops.auction_topk2(wm, prices)
            else:
                profits = wm - prices[None, :]
                w1 = jnp.max(profits, axis=1)
                jstar = jnp.argmax(profits, axis=1).astype(jnp.int32)
                second = jnp.where(cols[None, :] == jstar[:, None], _NEG,
                                   profits)
                w2 = jnp.max(second, axis=1)
            bidding = (assign == -1) & row_valid
            bid_val = w1 + prices[jstar] - w2 + eps   # = w[i,j*] - w2 + eps

            # dense bid matrix: rows bid on their jstar only (gather-only
            # conflict resolution — no duplicate-index scatters)
            bid_mat = jnp.where(
                bidding[:, None] & (cols[None, :] == jstar[:, None]),
                bid_val[:, None], _NEG)
            col_best = jnp.max(bid_mat, axis=0)
            col_winner = jnp.argmax(bid_mat, axis=0).astype(jnp.int32)
            has_bid = col_best > _NEG / 2

            # eviction: person i loses its object if that object was re-awarded
            cur_j = jnp.clip(assign, 0, K - 1)
            holds = assign >= 0
            evict = holds & has_bid[cur_j] & (col_winner[cur_j] != rows)

            # award: person i wins iff it bid on jstar[i] and won the argmax
            won = bidding & has_bid[jstar] & (col_winner[jstar] == rows)

            assign = jnp.where(won, jstar,
                               jnp.where(evict, jnp.int32(-1), assign))
            prices = jnp.where(has_bid, col_best, prices)

            d = dual_bound(prices)
            ub_best = jnp.minimum(ub_best, d)
            early = early | (ub_best < theta_lb)
            return assign, prices, ub_best, early, r + 1

        assign, prices, ub_best, early, r = jax.lax.while_loop(
            cond, body, (assign0, prices, ub_best, early, jnp.int32(0)))
        return (assign, eps, prices, ub_best, early, total_rounds + r), None

    prices0 = jnp.zeros((K,), dtype=jnp.float32)
    ub0 = dual_bound(prices0)
    carry0 = (jnp.full((K,), -1, dtype=jnp.int32), jnp.float32(0.0),
              prices0, ub0, jnp.bool_(False), jnp.int32(0))
    (assign, _, prices, ub_best, early, rounds), _ = jax.lax.scan(
        phase, carry0, eps_schedule)
    converged = jnp.all((assign >= 0) | ~row_valid)

    matched = (assign >= 0) & row_valid
    gathered = wm[rows, jnp.clip(assign, 0, K - 1)]
    lb = jnp.sum(jnp.where(matched, gathered, 0.0))
    eps_final = eps_schedule[-1]
    # eps-CS slack is one eps per *logical* person plus the price mass of
    # unassigned columns (0 in the common case — see docstring).
    leftover = jnp.sum(jnp.where(_cols_taken(assign), 0.0, prices))
    ub = jnp.where(converged & ~early,
                   jnp.minimum(ub_best,
                               lb + nq.astype(jnp.float32) * eps_final
                               + leftover),
                   ub_best)
    # an early-stopped element's lb is not meaningful; its ub < theta_lb is.
    lb = jnp.where(early, 0.0, lb)
    return lb, jnp.maximum(ub, lb), assign[:N], early, rounds


def make_eps_schedule(eps_min: float, eps_start: float = 0.25,
                      factor: float = 0.2) -> jnp.ndarray:
    eps = []
    e = eps_start
    while e > eps_min:
        eps.append(e)
        e *= factor
    eps.append(eps_min)
    return jnp.asarray(eps, dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=("max_rounds", "use_kernel"))
def auction_batch(w, nq, nc, eps_schedule, theta_lb, max_rounds: int = 5000,
                  use_kernel: bool = False):
    """Batched verification.

    Args:
      w: (B, N, M) padded weight matrices (alpha-thresholded, in [0, 1]).
      nq, nc: (B,) logical sizes.
      eps_schedule: (P,) descending epsilons from :func:`make_eps_schedule`.
      theta_lb: pruning threshold (Lemma 8) — scalar, or (B,) per-element
        when one batch carries several queries' verifications (the shared
        multi-query verify queue); use -inf to disable.
      use_kernel: run each round's profit top-2 through the fused Pallas
        kernel (``kernels/auction_round.py``) — the TPU serving/fused-wave
        path; the default inline jnp pass is the same math (guarded by a
        parity test) and faster under CPU interpret mode.
    Returns :class:`AuctionResult` of per-element score brackets.
    """
    theta = jnp.broadcast_to(
        jnp.asarray(theta_lb, jnp.float32), nq.shape)
    fn = jax.vmap(
        lambda wi, nqi, nci, ti: _auction_single(
            wi, nqi, nci, eps_schedule, ti, max_rounds,
            use_kernel=use_kernel))
    lb, ub, assign, early, rounds = fn(w, nq, nc, theta)
    return AuctionResult(lb=lb, ub=ub, assign=assign,
                         early_stopped=early, rounds=rounds)


def auction_score_bounds(w, eps_min: float = 1e-4, theta_lb: float = -1e30):
    """Single-matrix convenience wrapper; returns (lb, ub)."""
    w = jnp.asarray(w, dtype=jnp.float32)
    nq = jnp.int32(w.shape[0])
    nc = jnp.int32(w.shape[1])
    res = auction_batch(w[None], nq[None], nc[None],
                        make_eps_schedule(eps_min),
                        jnp.float32(theta_lb))
    return res.lb[0], res.ub[0]
