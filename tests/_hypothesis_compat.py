"""Seeded-RNG stand-in for ``hypothesis`` when it is not installed.

The seed environment ships without ``hypothesis``; importing this module
(from ``conftest.py``, before test collection) installs a minimal shim into
``sys.modules`` so that ``from hypothesis import given, settings,
strategies as st`` keeps working.  The shim re-runs each property test
``max_examples`` times with values drawn from a deterministically seeded
``numpy`` RNG — a plain randomized sweep, no shrinking.  When the real
``hypothesis`` is available (see requirements-dev.txt) it wins and the shim
is inert.

Only the strategy surface this repo uses is provided: ``integers``,
``floats``, ``booleans``, ``sampled_from``, ``lists``.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored) -> _Strategy:
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq) -> _Strategy:
    choices = list(seq)
    return _Strategy(lambda rng: choices[int(rng.integers(len(choices)))])


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    """Accepts (and mostly ignores) hypothesis settings kwargs."""
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    """Run the test once per example with strategy draws appended.

    Strategies bind to the *rightmost* positional parameters (hypothesis
    semantics); any leading parameters stay visible to pytest as fixtures.
    """
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        assert len(params) >= len(strategies), fn
        fixture_params = params[:len(params) - len(strategies)]
        drawn_names = [p.name for p in params[len(fixture_params):]]
        seed_base = zlib.crc32(
            f"{fn.__module__}.{fn.__qualname__}".encode())

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            for i in range(getattr(wrapper, "_max_examples",
                                   _DEFAULT_MAX_EXAMPLES)):
                rng = np.random.default_rng([seed_base, i])
                drawn = {name: s.draw(rng)
                         for name, s in zip(drawn_names, strategies)}
                fn(*args, **kwargs, **drawn)

        # pytest must see only the fixture parameters; drop the
        # functools.wraps __wrapped__ so the original signature (which
        # still lists the strategy-bound params) is not re-discovered.
        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper._max_examples = getattr(fn, "_max_examples",
                                       _DEFAULT_MAX_EXAMPLES)
        return wrapper
    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` if the real package is absent."""
    try:
        import hypothesis  # noqa: F401 — real package wins
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists"):
        setattr(strat, name, globals()[name])
    mod.strategies = strat
    mod.__is_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat


install()
