"""AdamW in pure JAX with large-scale state-dtype options.

``state_dtype='float32'`` is the standard choice; ``'bfloat16'`` halves the
optimizer-state HBM footprint (the binding memory term for the 671B-scale
dry-run configs, see EXPERIMENTS.md §Dry-run) using stochastic rounding on
the first moment to avoid update bias."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"      # 'float32' | 'bfloat16'


def _to_state_dtype(x, dtype, key=None):
    if dtype == jnp.bfloat16 and key is not None:
        # stochastic rounding: add uniform noise below the bf16 mantissa step
        scale = jnp.abs(x) * 2 ** -8
        noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
        return (x + noise * scale).astype(jnp.bfloat16)
    return x.astype(dtype)


def init(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32

    def zeros(p):
        return {"m": jnp.zeros(p.shape, dt), "v": jnp.zeros(p.shape, dt)}

    return {"mu": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0,
           rng: Optional[jax.Array] = None):
    """Returns (new_params, new_state).  Math in fp32 regardless of the
    param/state dtype; params are updated in their own dtype."""
    count = state["count"] + 1
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    use_sr = cfg.state_dtype == "bfloat16" and rng is not None

    def one(g, mu, p, key):
        g = g.astype(jnp.float32)
        m = cfg.b1 * mu["m"].astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * mu["v"].astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * lr_scale * step
        return (new_p.astype(p.dtype),
                {"m": _to_state_dtype(m, dt, key), "v": v.astype(dt)})

    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    mu_leaves = treedef.flatten_up_to(state["mu"])
    p_leaves = treedef.flatten_up_to(params)
    out = [one(g, mu, p,
               jax.random.fold_in(rng, i) if use_sr else None)
           for i, (g, mu, p) in enumerate(zip(g_leaves, mu_leaves, p_leaves))]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_params, {"mu": new_mu, "count": count}
