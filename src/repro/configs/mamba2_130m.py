"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

Assigned: 24L d_model=768 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
[arXiv:2405.21060; unverified]"""
from repro.models import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, ngroups=1,
                  conv_width=4, chunk=128),
    tie_embeddings=True, subquadratic=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", num_layers=2, d_model=64,
        num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=512,
        ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, ngroups=1,
                      conv_width=4, chunk=8),
        tie_embeddings=True, subquadratic=True, dtype="float32",
        remat="none")
