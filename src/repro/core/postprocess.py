"""KOIOS post-processing phase (paper Alg. 2) — batched verification.

Survivors of the refinement carry bounds [lb, ub].  We repeatedly:

  1. theta_lb  = k-th largest lb (exact SO counts as lb);
  2. UB-filter: drop sets with ub <= theta_lb (cannot affect the top-k);
  3. No-EM (Lemma 7): sets with lb >= theta_ub (k-th largest ub) are in the
     answer *without* computing a matching;
  4. batch-verify the highest-ub remaining sets:  the whole batch runs
     simultaneously (vmap'd auction — the paper's thread pool becomes batch
     parallelism) with Lemma-8 dual-bound early termination at theta_lb;
     ambiguous auction brackets are re-verified exactly (Hungarian), so the
     search result is exact;
  5. stop when no unverified live set has ub > theta_lb; the answer is the
     top-k by lb.

Verification recomputes the (|Q| x |C|) similarity block on the fly (MXU)
instead of caching refinement similarities — see DESIGN.md §8 item 7.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .matching.auction import auction_batch, make_eps_schedule
from .matching.hungarian import hungarian_batch
from .types import SearchParams, SearchResult, SearchStats, SetCollection


def _pad_pow2(n: int, lo: int = 8) -> int:
    p = lo
    while p < n:
        p *= 2
    return p


class Verifier:
    """Batched exact-SO verification with Lemma-8 early termination."""

    def __init__(self, coll: SetCollection, query: np.ndarray, sim_provider,
                 params: SearchParams):
        self.coll = coll
        self.query = np.asarray(query, dtype=np.int32)
        self.sim = sim_provider
        self.params = params
        self.eps_schedule = make_eps_schedule(params.auction_eps)
        self.stats_em_early = 0
        self.stats_em_full = 0

    def weight_matrix(self, set_id: int) -> np.ndarray:
        toks = self.coll.get_set(int(set_id))
        s = np.asarray(self.sim.pairwise(self.query, toks))
        return np.where(s >= self.params.alpha, s, 0.0).astype(np.float32)

    def _batch_weights(self, ids):
        """Pad batch to verify_batch and columns to pow2 so the vmap'd
        verifiers compile O(log max-set-size) distinct shapes."""
        mats = [self.weight_matrix(i) for i in ids]
        nq = len(self.query)
        nq_pad = _pad_pow2(nq)          # logical nq passed separately
        c_pad = _pad_pow2(max(m.shape[1] for m in mats))
        B = max(self.params.verify_batch, len(ids))
        w = np.zeros((B, nq_pad, c_pad), np.float32)
        ncs = np.zeros(B, np.int32)
        for b, m in enumerate(mats):
            w[b, :nq, :m.shape[1]] = m
            ncs[b] = m.shape[1]
        return w, ncs

    def verify(self, ids, theta_lb: float):
        """Returns (lb, ub, early) arrays for the given set ids.

        Brackets are exact (lb == ub == SO) unless early-terminated, in
        which case ub < theta_lb certifies exclusion (Lemma 8).
        """
        ids = np.asarray(ids)
        n = len(ids)
        w, ncs = self._batch_weights(ids)
        nqs = np.full(len(w), len(self.query), np.int32)
        if self.params.verifier == "hungarian":
            so, _ = hungarian_batch(jnp.asarray(w), jnp.asarray(nqs),
                                    jnp.asarray(ncs))
            so = np.asarray(so)[:n]
            self.stats_em_full += n
            return so.copy(), so.copy(), np.zeros(n, bool)

        res = auction_batch(jnp.asarray(w), jnp.asarray(nqs),
                            jnp.asarray(ncs), self.eps_schedule,
                            jnp.float32(theta_lb))
        lb = np.asarray(res.lb)[:n].copy()
        ub = np.asarray(res.ub)[:n].copy()
        early = np.asarray(res.early_stopped)[:n].copy()
        self.stats_em_early += int(early.sum())
        self.stats_em_full += int((~early).sum())

        # exact fallback for brackets that straddle theta_lb (cannot decide)
        ambiguous = (~early) & (lb < theta_lb) & (ub > theta_lb)
        # also tighten any non-degenerate bracket so downstream ordering is
        # exact when hybrid mode is requested
        if self.params.verifier == "hybrid":
            ambiguous |= (~early) & (ub - lb > 1e-6)
        if ambiguous.any():
            amb_ids = ids[ambiguous]
            w2, ncs2 = self._batch_weights(amb_ids)
            so, _ = hungarian_batch(
                jnp.asarray(w2),
                jnp.asarray(np.full(len(w2), len(self.query), np.int32)),
                jnp.asarray(ncs2))
            so = np.asarray(so)[:len(amb_ids)]
            lb[ambiguous] = so
            ub[ambiguous] = so
            self.stats_em_full += len(amb_ids)
        return lb, ub, early


def run_postprocess(coll: SetCollection, query: np.ndarray, sim_provider,
                    surv_ids: np.ndarray, surv_lb: np.ndarray,
                    surv_ub: np.ndarray, theta_lb0: float,
                    params: SearchParams,
                    stats: SearchStats) -> SearchResult:
    k = params.k
    ids = np.asarray(surv_ids)
    lb = np.asarray(surv_lb, np.float64).copy()
    ub = np.asarray(surv_ub, np.float64).copy()
    n = len(ids)
    live = np.ones(n, bool)
    verified = np.zeros(n, bool)
    verifier = Verifier(coll, query, sim_provider, params)

    def kth(x, mask, kk):
        vals = x[mask]
        if len(vals) < kk:
            return 0.0
        return float(np.partition(vals, -kk)[-kk])

    theta_lb = max(theta_lb0, kth(lb, live, k))
    guard = 0
    while True:
        guard += 1
        assert guard < 10 * n + 100, "post-processing failed to converge"
        theta_lb = max(theta_lb, kth(lb, live, k))
        # UB filter (sets that can no longer reach the top-k; strict <
        # keeps ties, which is always safe)
        drop = live & (ub < theta_lb)
        stats.pruned_postprocess += int((drop & ~verified).sum())
        live &= ~drop
        theta_ub = kth(ub, live, k)
        no_em = live & ~verified & (lb >= theta_ub)     # Lemma 7
        need = live & ~verified & (ub > theta_lb) & ~no_em
        if not need.any():
            stats.pruned_no_em += int(no_em.sum())
            break
        # verify the highest-ub pending sets as one batch
        order = np.argsort(-ub[need.nonzero()[0]])
        batch_idx = need.nonzero()[0][order[:params.verify_batch]]
        blb, bub, bearly = verifier.verify(ids[batch_idx], theta_lb)
        lb[batch_idx] = np.maximum(lb[batch_idx], blb)
        ub[batch_idx] = np.minimum(ub[batch_idx], bub)
        verified[batch_idx] = True
        # early-terminated sets are certified below theta_lb
        live[batch_idx[bearly]] = False

    # ---- assemble final top-k by lb --------------------------------------
    cand = live.nonzero()[0]
    order = cand[np.argsort(-lb[cand], kind="stable")][:k]

    if params.exact_scores and len(order):
        pend = order[~verified[order]]
        if len(pend):
            blb, bub, _ = verifier.verify(ids[pend], -np.inf)
            lb[pend] = blb
            ub[pend] = bub
            verified[pend] = True
        order = cand[np.argsort(-lb[cand], kind="stable")][:k]

    stats.pruned_em_early += verifier.stats_em_early
    stats.exact_matches += verifier.stats_em_full
    stats.theta_lb_final = float(theta_lb)
    return SearchResult(
        ids=ids[order].astype(np.int32),
        lb=lb[order].astype(np.float32),
        ub=ub[order].astype(np.float32),
        stats=stats,
    )
