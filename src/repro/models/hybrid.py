"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block.

The assigned zamba2-2.7b config: 54 mamba2 layers (ssm_state=64); one
transformer block (32 heads, d_ff=10240) whose weights are SHARED across
its periodic applications (every ``attn_every`` = 6 mamba layers -> 9
applications, each with its own KV cache).  Deviation noted in
configs/zamba2_2p7b.py: the original concatenates the raw embedding and
applies per-invocation LoRA; we apply the shared block on the residual
stream directly.

Structure: scan over 9 groups; each group = inner scan over 6 mamba blocks,
then the shared attention+MLP block."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention, attention_init, blocked_xent, dtype_of,
                     embed, embed_init, rmsnorm, rmsnorm_init, softmax_xent,
                     swiglu, swiglu_init, unembed)
from .ssm_lm import _block_apply, _block_decode, _block_init


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = dtype_of(cfg.dtype)
        self.every = cfg.hybrid.attn_every
        assert cfg.num_layers % self.every == 0
        self.n_groups = cfg.num_layers // self.every

    def init(self, key):
        cfg = self.cfg
        k0, k1, k2, k3, k4 = jax.random.split(key, 5)
        keys = jax.random.split(k1, cfg.num_layers)
        layers = [_block_init(k, cfg, self.dtype) for k in keys]
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
        # reshape to (groups, every, ...)
        stacked = jax.tree_util.tree_map(
            lambda x: x.reshape((self.n_groups, self.every) + x.shape[1:]),
            stacked)
        params = {
            "embed": embed_init(k0, cfg.vocab_size, cfg.d_model, self.dtype),
            "mamba": stacked,
            "shared": {
                "attn_norm": rmsnorm_init(cfg.d_model, self.dtype),
                "attn": attention_init(k2, cfg, self.dtype),
                "mlp_norm": rmsnorm_init(cfg.d_model, self.dtype),
                "mlp": swiglu_init(k3, cfg.d_model, cfg.d_ff, self.dtype),
            },
            "final_norm": rmsnorm_init(cfg.d_model, self.dtype),
        }
        if not cfg.tie_embeddings:
            out = jax.random.normal(k4, (cfg.d_model, cfg.vocab_size),
                                    jnp.float32) * cfg.d_model ** -0.5
            params["out"] = {"table": out.T.astype(self.dtype)}
        return params

    def param_specs(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    def _logits(self, params, x):
        head = params["embed"] if self.cfg.tie_embeddings or \
            "out" not in params else params["out"]
        return unembed(head, x)

    def _shared_block(self, shared, x, positions, cache=None,
                      cache_index=None):
        h = rmsnorm(shared["attn_norm"], x)
        a, new_cache = attention(shared["attn"], self.cfg, h, positions,
                                 cache=cache, cache_index=cache_index)
        x = x + a
        x = x + swiglu(shared["mlp"], rmsnorm(shared["mlp_norm"], x))
        return x, new_cache

    def _backbone(self, params, x, positions):
        cfg = self.cfg
        shared = params["shared"]

        def group(h, group_p):
            def inner(hh, layer_p):
                hh, cache = _block_apply(layer_p, cfg, hh)
                return hh, cache

            fn = jax.checkpoint(inner) if cfg.remat != "none" else inner
            h, m_caches = jax.lax.scan(fn, h, group_p,
                                       unroll=cfg.scan_unroll)
            h, a_cache = self._shared_block(shared, h, positions)
            return h, (m_caches, a_cache)

        x, (m_caches, a_caches) = jax.lax.scan(group, x, params["mamba"],
                                               unroll=cfg.scan_unroll)
        return rmsnorm(params["final_norm"], x), m_caches, a_caches

    def loss(self, params, batch):
        x = embed(params["embed"], batch["tokens"])
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        x, _, _ = self._backbone(params, x, positions)
        if self.cfg.xent_block:
            head = params["embed"] if self.cfg.tie_embeddings or \
                "out" not in params else params["out"]
            return blocked_xent(x[:, :-1], head["table"],
                                batch["labels"][:, 1:], self.cfg.xent_block)
        logits = self._logits(params, x)
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:])

    # ------------------------------------------------------------- serving
    def cache_specs(self, batch: int, max_seq: int):
        cfg = self.cfg
        s = cfg.ssm
        inner = s.expand * cfg.d_model
        H = inner // s.head_dim
        gs = s.ngroups * s.state_dim
        G, E = self.n_groups, self.every
        K = s.conv_width
        return {
            "ssm": jax.ShapeDtypeStruct(
                (G, E, batch, H, s.head_dim, s.state_dim), jnp.float32),
            "cx": jax.ShapeDtypeStruct((G, E, batch, K - 1, inner),
                                       self.dtype),
            "cb": jax.ShapeDtypeStruct((G, E, batch, K - 1, gs), self.dtype),
            "cc": jax.ShapeDtypeStruct((G, E, batch, K - 1, gs), self.dtype),
            "k": jax.ShapeDtypeStruct(
                (G, batch, max_seq, cfg.num_kv_heads, cfg.hd), self.dtype),
            "v": jax.ShapeDtypeStruct(
                (G, batch, max_seq, cfg.num_kv_heads, cfg.hd), self.dtype),
        }

    def init_cache(self, batch: int, max_seq: int):
        return jax.tree_util.tree_map(
            lambda sp: jnp.zeros(sp.shape, sp.dtype),
            self.cache_specs(batch, max_seq))

    def prefill(self, params, batch, max_seq=None):
        x = embed(params["embed"], batch["tokens"])
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        x, m_caches, a_caches = self._backbone(params, x, positions)
        if max_seq is not None and max_seq > S:
            a_caches = jax.tree_util.tree_map(
                lambda c: jnp.pad(
                    c, [(0, 0), (0, 0), (0, max_seq - S), (0, 0), (0, 0)]),
                a_caches)
        caches = {"ssm": m_caches["ssm"], "cx": m_caches["cx"],
                  "cb": m_caches["cb"], "cc": m_caches["cc"],
                  "k": a_caches["k"], "v": a_caches["v"]}
        return self._logits(params, x[:, -1:]), caches

    def decode_step(self, params, caches, token, cache_index):
        cfg = self.cfg
        x = embed(params["embed"], token)
        B = x.shape[0]
        positions = jnp.full((B, 1), cache_index, jnp.int32)
        shared = params["shared"]

        def group(h, xs):
            group_p, m_cache, kv = xs

            def inner(hh, ys):
                layer_p, cache = ys
                hh, new = _block_decode(layer_p, cfg, hh, cache)
                return hh, new

            h, new_m = jax.lax.scan(inner, h, (group_p, m_cache),
                                    unroll=cfg.scan_unroll)
            h, new_kv = self._shared_block(shared, h, positions, cache=kv,
                                           cache_index=cache_index)
            return h, (new_m, new_kv)

        m_caches = {"ssm": caches["ssm"], "cx": caches["cx"],
                    "cb": caches["cb"], "cc": caches["cc"]}
        kv = {"k": caches["k"], "v": caches["v"]}
        x, (new_m, new_kv) = jax.lax.scan(
            group, x, (params["mamba"], m_caches, kv),
            unroll=cfg.scan_unroll)
        x = rmsnorm(params["final_norm"], x)
        caches = {"ssm": new_m["ssm"], "cx": new_m["cx"],
                  "cb": new_m["cb"], "cc": new_m["cc"],
                  "k": new_kv["k"], "v": new_kv["v"]}
        return self._logits(params, x), caches
