"""Host<->device dispatch accounting (the fused-wave A/B metric).

The fused wave program's whole point is eliminating host round-trips
(DESIGN.md §3 / §8 item 6 resolution), so the benchmark needs a number
to show for it.  ``counting()`` installs a process-local counter; every
host->device program dispatch and device->host materialization on the
search path calls :func:`record` with an event tag.  Outside a
``counting()`` block recording is a no-op (one ``is None`` check — the
hot path pays nothing).

Tags follow ``<direction>:<site>``: ``h2d`` = a program dispatch,
``d2h`` = a blocking device-to-host materialization.  The A/B in
``benchmarks/response_time.py --fused`` reports the per-direction sums.
"""
from __future__ import annotations

import contextlib
from collections import Counter
from typing import Iterator, Optional

_ACTIVE: Optional[Counter] = None


def record(event: str, n: int = 1) -> None:
    """Count ``n`` occurrences of ``event`` if a counter is installed."""
    if _ACTIVE is not None:
        _ACTIVE[event] += n


@contextlib.contextmanager
def counting() -> Iterator[Counter]:
    """Install a fresh dispatch counter for the enclosed block (reentrant:
    an inner block shadows, then restores, the outer one)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = Counter()
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def totals(counts: Counter) -> dict:
    """Per-direction sums plus the grand total of a counter's events."""
    h2d = sum(v for k, v in counts.items() if k.startswith("h2d:"))
    d2h = sum(v for k, v in counts.items() if k.startswith("d2h:"))
    return {"h2d_dispatches": h2d, "d2h_transfers": d2h,
            "total": h2d + d2h}
