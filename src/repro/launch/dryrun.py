import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below happens only after the device count is pinned --------
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import numpy as np       # noqa: E402
import jax               # noqa: E402

from repro.configs import get_config, list_archs                 # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch.steps import (make_decode_step, make_prefill_step,  # noqa: E402
                                make_train_step, train_shardings)
from repro.models import SHAPES, build, input_specs, shape_applicable  # noqa: E402
from repro.models.config import ModelConfig                      # noqa: E402
from repro.runtime.hlo_analysis import (normalize_cost_analysis,  # noqa: E402
                                        parse_collectives,
                                        roofline_terms, PEAK_FLOPS)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell this produces (and persists to experiments/dryrun/*.json):

  * PRODUCTION compile: the full-depth scanned model on the (16,16) pod
    mesh and the (2,16,16) two-pod mesh — memory_analysis() proves the
    per-device footprint, the collective census proves the sharding is
    coherent (correct axes, no accidental full-replication gathers).
  * COST PROBES (single-pod only): XLA:CPU cost_analysis does not multiply
    while-loop trip counts (calibrated in _calibrate: a lax.scan body is
    counted exactly once), so per-layer costs are measured from two or
    three small UNROLLED probe compiles at the same mesh/shapes and
    extrapolated linearly to full depth:
        F(total) = F(fixed) + sum_stack L_stack * F(layer_stack).
    The same extrapolation covers bytes-accessed and collective link bytes.
  * Roofline terms (compute/memory/collective, seconds/step/device) from
    the extrapolated totals + v5e constants, plus MODEL_FLOPS = 6*N*D
    (resp. 2*N*D for decode) and the useful-compute ratio.
"""


# --------------------------------------------------------------- utilities

def _tree_bytes(tree) -> int:
    return sum(int(np.prod(s.shape)) * s.dtype.itemsize
               for s in jax.tree_util.tree_leaves(tree))


def _param_count(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree_util.tree_leaves(specs))


def _nonembed_param_count(specs) -> int:
    total = 0
    for path, s in jax.tree_util.tree_flatten_with_path(specs)[0]:
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if "table" in ps:
            continue
        total += int(np.prod(s.shape))
    return total


def _calibrate() -> dict:
    """Verify the two cost-analysis facts the methodology relies on."""
    A = jax.ShapeDtypeStruct((256, 256), jax.numpy.float32)
    f1 = normalize_cost_analysis(
        jax.jit(lambda a, b: a @ b).lower(A, A).compile()
        .cost_analysis())["flops"]
    mac2 = abs(f1 / (2 * 256 ** 3) - 1.0) < 0.05

    W = jax.ShapeDtypeStruct((8, 256, 256), jax.numpy.float32)

    def scanned(x, ws):
        return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]

    f2 = normalize_cost_analysis(
        jax.jit(scanned).lower(A, W).compile().cost_analysis())["flops"]
    loop_once = abs(f2 / (2 * 256 ** 3) - 1.0) < 0.05
    return {"mac_is_2flops": bool(mac2),
            "scan_body_counted_once": bool(loop_once)}


def _compile_cell(cfg: ModelConfig, shape_name: str, mesh):
    """Lower+compile the production (scanned) step.  Returns compiled."""
    kind = SHAPES[shape_name][2]
    with mesh:
        if kind == "train":
            train_step, model, state_specs, state_ps = make_train_step(
                cfg, mesh)
            batch_specs, in_sh, out_sh = train_shardings(
                cfg, mesh, state_ps, shape_name)
            lowered = jax.jit(train_step, in_shardings=in_sh,
                              out_shardings=out_sh,
                              donate_argnums=0).lower(state_specs,
                                                      batch_specs)
        elif kind == "prefill":
            step, arg_specs, in_sh, out_sh = make_prefill_step(
                cfg, mesh, shape_name)
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*arg_specs)
        else:
            step, arg_specs, in_sh, out_sh = make_decode_step(
                cfg, mesh, shape_name)
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh,
                              donate_argnums=1).lower(*arg_specs)
        compiled = lowered.compile()
    return compiled


def _measure(compiled) -> dict:
    ma = compiled.memory_analysis()
    ca = normalize_cost_analysis(compiled.cost_analysis())
    txt = compiled.as_text()
    coll = parse_collectives(txt)
    return {
        "flops_reported": float(ca.get("flops", 0.0)),
        "bytes_reported": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll.as_dict(),
        "link_bytes_reported": coll.link_bytes(),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes),
        },
    }


# ------------------------------------------------------------ cost probes

def _probe_variants(cfg: ModelConfig):
    """(name, probe_cfg, depth_vector) per probe compile + the full depth
    vector; costs are linear in the depth vector."""
    u = dict(scan_unroll=True)
    if cfg.family in ("dense", "vlm", "ssm"):
        full = np.array([1, cfg.num_layers])
        mk = lambda L: cfg.with_(num_layers=L, **u)
        return [("L2", mk(2), np.array([1, 2])),
                ("L4", mk(4), np.array([1, 4]))], full
    if cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        full = np.array([1, fd, cfg.num_layers - fd])
        if fd == 0:
            mk = lambda m: cfg.with_(num_layers=m, **u)
            return [("M2", mk(2), np.array([1, 0, 2])),
                    ("M4", mk(4), np.array([1, 0, 4]))], full

        def mk(d, m):
            return cfg.with_(
                num_layers=d + m,
                moe=dataclasses.replace(cfg.moe, first_dense_layers=d), **u)
        return [("D2M2", mk(2, 2), np.array([1, 2, 2])),
                ("D4M2", mk(4, 2), np.array([1, 4, 2])),
                ("D2M4", mk(2, 4), np.array([1, 2, 4]))], full
    if cfg.family == "hybrid":
        e = cfg.hybrid.attn_every
        full = np.array([1, cfg.num_layers // e])
        mk = lambda g: cfg.with_(num_layers=g * e, **u)
        return [("G1", mk(1), np.array([1, 1])),
                ("G2", mk(2), np.array([1, 2]))], full
    if cfg.family == "audio":
        full = np.array([1, cfg.enc_layers, cfg.num_layers])

        def mk(e, d):
            return cfg.with_(enc_layers=e, num_layers=d, **u)
        return [("E2D2", mk(2, 2), np.array([1, 2, 2])),
                ("E4D2", mk(4, 2), np.array([1, 4, 2])),
                ("E2D4", mk(2, 4), np.array([1, 2, 4]))], full
    raise ValueError(cfg.family)


def _probe_costs(cfg: ModelConfig, shape_name: str, mesh) -> dict:
    """Extrapolated per-device (flops, bytes, link_bytes) at full depth."""
    probes, full = _probe_variants(cfg)
    rows, obs = [], []
    for name, pcfg, depth in probes:
        compiled = _compile_cell(pcfg, shape_name, mesh)
        m = _measure(compiled)
        rows.append(depth)
        obs.append([m["flops_reported"], m["bytes_reported"],
                    m["link_bytes_reported"]])
        del compiled
    A = np.stack(rows).astype(np.float64)            # (n_probes, n_terms)
    Y = np.array(obs)                                # (n_probes, 3)
    coef, *_ = np.linalg.lstsq(A, Y, rcond=None)     # (n_terms, 3)
    totals = np.maximum(full.astype(np.float64) @ coef, 0.0)   # (3,)
    per_layer = {f"stack{i}": coef[i].tolist()
                 for i in range(1, coef.shape[0])}
    return {"flops": float(totals[0]), "bytes": float(totals[1]),
            "link_bytes": float(totals[2]),
            "fixed": coef[0].tolist(), "per_layer": per_layer,
            "probes": [p[0] for p in probes]}


def _model_flops(cfg: ModelConfig, shape_name: str, specs) -> float:
    """Analytic MODEL_FLOPS (global per step): 6*N*D train, 2*N*D fwd."""
    seq, batch, kind = SHAPES[shape_name]
    n = _nonembed_param_count(specs)
    if cfg.moe is not None:
        m = cfg.moe
        # active experts: top_k + shared of num_experts per MoE layer
        moe_layers = cfg.num_layers - m.first_dense_layers
        per_layer_expert = 3 * cfg.d_model * m.d_ff_expert
        routed_total = moe_layers * m.num_experts * per_layer_expert
        routed_active = moe_layers * (m.top_k) * per_layer_expert
        n = n - routed_total + routed_active
    if kind == "train":
        return 6.0 * n * seq * batch
    if kind == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch           # decode: one token per sequence


# ---------------------------------------------------------------- variants
# §Perf hillclimb stages (EXPERIMENTS.md §Perf): each is a named config
# transform applied on top of the current code; baselines are the stored
# pre-optimization records.

def _v_xent(cfg):
    return cfg.with_(xent_block=8192)


def _v_moe_dispatch(cfg):
    if cfg.moe is None:
        return cfg
    return cfg.with_(moe=dataclasses.replace(cfg.moe, impl="dispatch"))


def _v_moe_gather(cfg):
    if cfg.moe is None:
        return cfg
    return cfg.with_(moe=dataclasses.replace(cfg.moe, impl="gather"))


def _v_moe_dispatch_xent(cfg):
    return _v_xent(_v_moe_dispatch(cfg))


def _v_remat_dots(cfg):
    return cfg.with_(remat="dots")


VARIANTS = {
    "gqa": lambda c: c,                 # code-level change; rerun baseline
    "xent": _v_xent,
    "moe_dispatch": _v_moe_dispatch,
    "moe_dispatch_xent": _v_moe_dispatch_xent,
    "moe_gather": _v_moe_gather,
    "moe_gather_xent_dots": lambda c: _v_remat_dots(_v_xent(_v_moe_gather(c))),
    "seqpar": lambda c: c.with_(attn_seq_parallel=True),
    "moe_gather_seqpar_dots": lambda c: _v_remat_dots(
        _v_moe_gather(c).with_(attn_seq_parallel=True)),
    "remat_dots": _v_remat_dots,
    "xent_remat_dots": lambda c: _v_remat_dots(_v_xent(c)),
}


# ------------------------------------------------------------------- main

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             probes: bool, variant: str | None = None) -> dict:
    cfg = get_config(arch)
    if variant:
        cfg = VARIANTS[variant](cfg)
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    out = {"arch": arch, "shape": shape_name, "variant": variant,
           "mesh": "multi" if multi_pod else "single", "chips": chips}
    t0 = time.time()
    compiled = _compile_cell(cfg, shape_name, mesh)
    out["compile_s"] = round(time.time() - t0, 1)
    out["status"] = "ok"
    out["production"] = _measure(compiled)
    print(compiled.memory_analysis())
    ca = normalize_cost_analysis(compiled.cost_analysis())
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    del compiled

    model = build(cfg)
    specs = model.param_specs()
    out["param_count"] = _param_count(specs)
    out["param_bytes_global"] = _tree_bytes(specs)

    if probes and not multi_pod:
        t0 = time.time()
        pc = _probe_costs(cfg, shape_name, mesh)
        out["probe_s"] = round(time.time() - t0, 1)
        out["extrapolated"] = pc
        terms = roofline_terms(pc["flops"], pc["bytes"], pc["link_bytes"])
        mf = _model_flops(cfg, shape_name, specs)
        terms["model_flops_global"] = mf
        terms["model_flops_per_device"] = mf / chips
        terms["useful_compute_ratio"] = (
            mf / chips / pc["flops"] if pc["flops"] else 0.0)
        terms["mfu_upper_bound"] = (
            (mf / chips / PEAK_FLOPS) / terms["step_lower_bound_s"]
            if terms["step_lower_bound_s"] else 0.0)
        out["roofline"] = terms
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default=None, choices=list(VARIANTS))
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cal = _calibrate()
    print("calibration:", cal)
    assert cal["mac_is_2flops"] and cal["scan_body_counted_once"], \
        "cost-analysis conventions changed; probe extrapolation invalid"

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape_name}_{'multi' if multi else 'single'}"
                if args.variant:
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {tag}")
                    continue
                print(f"[cell] {tag}", flush=True)
                try:
                    rec = run_cell(arch, shape_name, multi,
                                   probes=not args.no_probes,
                                   variant=args.variant)
                except Exception as e:          # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"  -> {rec['status']}"
                      + (f" compile={rec.get('compile_s')}s"
                         if rec.get("compile_s") else ""), flush=True)
    print(f"done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
