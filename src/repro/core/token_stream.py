"""The token stream I_e — chunked, blocked-matmul replacement for Faiss+PQ.

Paper §IV: I_e yields (q, t, sim(q, t)) tuples for every vocabulary token t
with sim >= alpha to some query element, in globally descending similarity
order, realised with a Faiss index plus a |Q|-slot priority queue.

TPU adaptation (DESIGN.md §2): the index probe is a blocked similarity
matmul (MXU) over vocabulary tiles — `repro.kernels.cosine_topk` is the
fused Pallas kernel for the serving path; here the same block computation
runs through the jnp provider and the >=alpha entries are compacted host
side (compaction is inherently dynamic-shape, i.e. host work in either
implementation — the paper also walks its priority queue on the host).

The refinement phase consumes the stream *expanded to posting-level events*
through the inverted index (paper: "probing I_s"), still in descending
order:  (set, q, slot, sim) per posting of each streamed token.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .inverted_index import InvertedIndex
from .types import SetCollection


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """All pairs (q position, token, sim >= alpha), descending by sim."""

    q_pos: np.ndarray    # (T,) int32 — position of the query element in Q
    token: np.ndarray    # (T,) int32 — vocabulary token id
    sim: np.ndarray      # (T,) float32, non-increasing

    def __len__(self) -> int:
        return len(self.sim)


@dataclasses.dataclass(frozen=True)
class EventStream:
    """Posting-level expansion of a TokenStream (still descending by sim)."""

    set_id: np.ndarray   # (E,) int32
    q_pos: np.ndarray    # (E,) int32
    slot: np.ndarray     # (E,) int64 — flat token-array slot (t-side identity)
    sim: np.ndarray      # (E,) float32, non-increasing
    n_tuples: int        # stream tuples that produced these events

    def __len__(self) -> int:
        return len(self.sim)


def build_token_stream(query: np.ndarray, sim_provider, alpha: float,
                       block_size: int = 4096) -> TokenStream:
    """Collect all (q, t, sim>=alpha) pairs via blocked similarity compute.

    ``sim_provider`` must expose ``query_vs_vocab_block(q_ids, lo, hi)`` and
    ``vocab_size``.  Identity pairs (q, q) are always included with sim 1.0
    (paper §V: a query element is returned for itself on first probe — this
    initialises bounds with the vanilla overlap and covers out-of-vocabulary
    elements).
    """
    query = np.asarray(query, dtype=np.int32)
    nq = len(query)
    vocab = sim_provider.vocab_size
    qs, ts, ss = [], [], []
    for lo in range(0, vocab, block_size):
        hi = min(lo + block_size, vocab)
        block = np.asarray(sim_provider.query_vs_vocab_block(query, lo, hi))
        qi, tj = np.nonzero(block >= alpha)
        if len(qi):
            qs.append(qi.astype(np.int32))
            ts.append((tj + lo).astype(np.int32))
            ss.append(block[qi, tj].astype(np.float32))
    if qs:
        q_pos = np.concatenate(qs)
        token = np.concatenate(ts)
        sim = np.concatenate(ss)
    else:
        q_pos = np.zeros(0, np.int32)
        token = np.zeros(0, np.int32)
        sim = np.zeros(0, np.float32)

    # Identity pairs (q, q, 1.0) — add any that the provider missed (e.g.
    # degenerate embeddings) and dedupe.
    in_vocab = query < vocab
    id_q = np.arange(nq, dtype=np.int32)[in_vocab]
    id_t = query[in_vocab]
    key = q_pos.astype(np.int64) * vocab + token
    id_key = id_q.astype(np.int64) * vocab + id_t
    missing = ~np.isin(id_key, key)
    q_pos = np.concatenate([q_pos, id_q[missing]])
    token = np.concatenate([token, id_t[missing]])
    sim = np.concatenate([sim, np.ones(missing.sum(), np.float32)])

    # identity pairs must carry sim exactly 1.0 even if the provider returned
    # a slightly different value
    ident = query[q_pos] == token
    sim = np.where(ident, np.float32(1.0), sim)

    order = np.argsort(-sim, kind="stable")
    return TokenStream(q_pos=q_pos[order], token=token[order], sim=sim[order])


def expand_to_events(stream: TokenStream, index: InvertedIndex) -> EventStream:
    """Expand stream tuples through the inverted index to per-set events."""
    counts = index.posting_counts()
    reps = counts[stream.token]
    set_id = np.empty(int(reps.sum()), dtype=np.int32)
    slot = np.empty(len(set_id), dtype=np.int64)
    q_pos = np.repeat(stream.q_pos, reps)
    sim = np.repeat(stream.sim, reps)
    out = 0
    for t, n in zip(stream.token, reps):
        if n:
            lo = index.tok_indptr[t]
            set_id[out:out + n] = index.posting_set[lo:lo + n]
            slot[out:out + n] = index.posting_slot[lo:lo + n]
            out += n
    return EventStream(set_id=set_id, q_pos=q_pos, slot=slot, sim=sim,
                       n_tuples=len(stream))


def pad_events(events: EventStream, chunk: int):
    """Pad event arrays to a power-of-two number of ``chunk``-sized chunks
    (set_id = -1 padding).  Pow2 chunk counts bound jit recompilations of the
    refinement scan to O(log stream-length) distinct shapes."""
    e = len(events)
    n_chunks = max(1, -(-e // chunk))
    p = 1
    while p < n_chunks:
        p *= 2
    n_chunks = p
    total = n_chunks * chunk
    pad = total - e

    def _pad(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])

    last_sim = events.sim[-1] if e else np.float32(1.0)
    return (
        _pad(events.set_id, -1).reshape(n_chunks, chunk),
        _pad(events.q_pos, 0).reshape(n_chunks, chunk),
        _pad(events.slot, 0).reshape(n_chunks, chunk),
        _pad(events.sim, last_sim).reshape(n_chunks, chunk),
    )
