"""Continuous-batching request engine (DESIGN.md §3.2).

The serving runtime the ROADMAP's "heavy traffic" north star asks for:
instead of the batch-synchronous demo loop (pre-form a batch, rebuild
streams and a plan from scratch, run it to completion, report one
amortized latency), :class:`RequestEngine` owns an explicit request
lifecycle

    admit -> stream -> plan -> waves -> postprocess -> respond

with cross-request reuse at every stage:

* **admit** — requests enter an admission queue with optional deadlines
  (earliest-deadline-first, FIFO among equals).  Nothing waits for a
  batch to "fill": every engine step coalesces whatever has arrived.
* **stream** — token streams come from an LRU
  :class:`~repro.core.token_stream.TokenStreamCache` keyed by
  (query tokens, alpha, provider): repeated or overlapping queries skip
  ``build_token_stream_batch`` entirely; the misses of a step build in
  ONE stacked sweep.
* **plan** — one long-lived :class:`~repro.core.scheduler.ExecutionPlan`
  absorbs joiners mid-flight (``plan.add_queries``): a request admitted
  while others are halfway through their partitions joins the very next
  wave.  Sound because a query's tiles read only its own theta carry and
  row-level numerics are schedule-invariant (DESIGN.md §3) — the final
  top-k is bit-identical to the one-shot ``search_batch`` path.
* **waves** — each step runs one wave: a tile per live request, each at
  its own next partition (``scheduler.run_wave``), or per-partition
  fused device programs (``scheduler.run_fused_wave``) through the
  engine-lifetime :func:`~repro.core.wave.wave_runner_for` runner.
  Batch shapes pad to the existing pow2 buckets, so steady-state serving
  triggers zero recompiles (tests/test_recompile.py).
* **respond** — per-request merge + true admit->respond latency from
  :class:`~repro.runtime.instrument.EngineCounters` (never an amortized
  batch figure).

The engine is single-threaded and synchronous — "continuous batching"
is a property of the schedule (mid-flight joins at wave boundaries), not
of host threading, exactly as in serving systems whose step loop owns
the batch (the vLLM lesson applied to set search).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.postprocess import VerifierPool
from ..core.scheduler import (ExecutionPlan, SchedulerStats, _exchange,
                              run_fused_wave, run_wave)
from ..core.search import KoiosIndex, merge_topk
from ..core.token_stream import (TokenStreamCache,
                                 build_token_stream_batch_cached)
from ..core.types import (QueryValidationError, SearchParams, SearchResult,
                          SearchStats, validate_query)
from .fault import (FaultConfig, FaultPlan, FleetMonitor, ReplicaCrash,
                    TransientVerifierError)
from .instrument import EngineCounters, RequestTrace, record


def _void_result() -> SearchResult:
    """The result payload of a non-served response (shed / failed): an
    empty top-k, never a partial one — served responses stay exactly
    bit-identical to the one-shot path or are not served at all."""
    return SearchResult(ids=np.zeros(0, np.int32),
                        lb=np.zeros(0, np.float32),
                        ub=np.zeros(0, np.float32), stats=SearchStats())


@dataclasses.dataclass
class _Request:
    """Engine-internal lifecycle record of one admitted request."""

    rid: int
    query: np.ndarray
    trace: RequestTrace
    arrival: float                       # visibility time (trace replay)
    seq: int                             # admission tiebreak (FIFO)
    qi: int = -1                         # plan query index once joined
    epoch: int = -1                      # collection epoch pinned at join
    pending: List[int] = dataclasses.field(default_factory=list)
    parts: Dict[int, SearchResult] = dataclasses.field(default_factory=dict)

    def priority(self) -> tuple:
        d = self.trace.deadline
        return (d if d is not None else float("inf"), self.seq)


@dataclasses.dataclass(frozen=True)
class EngineResponse:
    """What ``respond`` emits: the merged result + true per-request
    lifecycle timings (the numbers ``serve_batch`` used to fake with one
    amortized figure).

    ``status`` makes the outcome explicit (DESIGN.md §6) instead of
    implying success: ``ok`` = served, bit-identical to the one-shot
    path; ``shed`` = dropped before occupying a wave tile because its
    deadline was already unreachable (``result`` is empty); ``retried``
    = served ``ok`` after ``retries`` failover resubmissions (same
    exactness guarantee as ``ok``); ``failed`` = the retry budget ran
    out, no healthy replica existed, the admission queue was full
    (``overloaded``), or the query failed admission-time validation
    (``reason`` says which).

    ``epoch`` is the collection epoch the request was SERVED against
    (pinned at join, DESIGN.md §6.5): a served response is bit-identical
    to the one-shot path over that epoch's repository, whatever commits
    landed while it was in flight."""

    rid: int
    result: SearchResult
    latency_s: float                     # admit -> respond
    queue_s: float                       # admit -> first wave
    waves: int
    stream_hit: bool
    deadline_met: Optional[bool]
    status: str = "ok"                   # ok | shed | retried | failed
    retries: int = 0                     # failover resubmissions served
    reason: str = ""                     # shed/failed explanation
    epoch: int = 0                       # collection epoch served against

    @property
    def served(self) -> bool:
        return self.status in ("ok", "retried")


class RequestEngine:
    """Admission-queued, stream-cached, shape-bucketed search runtime.

    ``schedule``: ``"wave"`` drives host waves (works on any backend;
    ``"overlap"``/``"sequential"`` are accepted aliases — at wave
    granularity they coincide), ``"fused"`` runs each wave's
    per-partition groups as fused device programs where available
    (``core.wave.fused_available``; falls back to host waves).  Results
    are bit-identical across all of them and to the one-shot
    ``KoiosSearch.search_batch`` (tests/test_engine.py).

    ``clock``/``sleep`` are injectable for deterministic trace-replay
    tests; real serving uses the monotonic wall clock.

    Collection state lives in a :class:`ShardedCollection` resource —
    pass ``collection=`` to serve an existing (possibly placed, possibly
    shared-with-other-replicas) resource, or let the constructor build a
    private one from ``coll``/``partitions``/``partition_by``
    (``indexes=`` adopts prebuilt partition indexes into a resource —
    benchmarks sharing one index build).  The engine borrows per-shard
    operand views; it owns no collection device arrays.
    """

    def __init__(self, coll, sim_provider,
                 params: Optional[SearchParams] = None,
                 partitions: int = 1, schedule: str = "wave",
                 partition_by: str = "sets",
                 bound_exchange: Optional[Callable] = None, mesh=None,
                 stream_cache_bytes: int = 64 << 20,
                 max_wave_requests: int = 64,
                 max_pending: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 indexes: Optional[Sequence[KoiosIndex]] = None,
                 collection=None,
                 shed_deadlines: bool = False,
                 fault_plan: Optional[FaultPlan] = None,
                 replica_id: int = 0,
                 monitor: Optional[FleetMonitor] = None):
        from .collection import ShardedCollection

        self.params = params or SearchParams()
        self.sim = sim_provider
        if collection is None:
            collection = (ShardedCollection.adopt(coll, indexes)
                          if indexes is not None else
                          ShardedCollection.build(coll, partitions,
                                                  by=partition_by))
        self.collection = collection
        # pin the epoch this engine serves: every joined request computes
        # against this consistent snapshot until resync() (DESIGN.md §6.5)
        self._epoch = collection.pin()
        self.coll = self._epoch.coll
        self.bound_exchange = bound_exchange
        self.mesh = mesh
        self.clock = clock
        self._sleep = sleep
        self.max_wave_requests = int(max_wave_requests)
        # bounded admission: past max_pending, submit responds
        # status='failed' reason='overloaded' instead of growing without
        # bound (None = unbounded — the historical behavior)
        self.max_pending = max_pending if max_pending is None \
            else int(max_pending)
        self.partitions = self._epoch.shards

        if schedule in ("overlap", "sequential"):
            schedule = "wave"
        assert schedule in ("wave", "fused"), schedule
        self._runner = None
        if schedule == "fused":
            from ..core.wave import fused_available, wave_runner_for
            if fused_available(self.params, sim_provider):
                self._runner = wave_runner_for(sim_provider, self.params,
                                               mesh=mesh)
            else:
                schedule = "wave"
        self.schedule = schedule

        # engine-lifetime shared machinery (the cross-request reuse)
        self.plan = ExecutionPlan(self.partitions, [], pool_coll=self.coll,
                                  epoch=self._epoch.epoch)
        self.pool = VerifierPool(self.coll, sim_provider, self.params)
        self.stream_cache = TokenStreamCache(max_bytes=stream_cache_bytes)
        self.stream_cache.set_epoch(self._epoch.epoch)
        self.counters = EngineCounters()

        self._streams: List[object] = []          # aligned with plan.queries
        self._theta: List[float] = []             # per-query carry
        self._tiles: Dict[int, Dict[int, object]] = {}   # qi -> pi -> tile
        self._rid = itertools.count()
        self._seq = itertools.count()
        self._arrivals: List[_Request] = []       # future visibility
        self._queue: List[_Request] = []          # admitted, awaiting join
        self._inflight: Dict[int, _Request] = {}  # rid -> joined request
        self._completed: List[EngineResponse] = []

        # ---- fault-tolerant serving plane (DESIGN.md §6) ----
        # shed_deadlines: drop requests whose deadline is already
        # unreachable BEFORE they occupy a wave tile (status='shed');
        # off by default — shedding changes which requests are answered,
        # so it is an explicit serving policy, never a silent one.
        self.shed_deadlines = bool(shed_deadlines)
        self.fault_plan = fault_plan
        self.replica_id = int(replica_id)
        self.monitor = monitor
        self._step_no = 0                         # 1-based after first step
        self._wave_ewma = 0.0                     # smoothed wave seconds
        self._last_wave = 0                       # tiles run by last step

        # ---- epoch rollout (DESIGN.md §6.5) ----
        # standalone engines resync at the first drained step boundary
        # after a commit; a router serializes the rollout by granting
        # _resync_allowed to one behind replica at a time
        self._resync_allowed = True
        self._warm_sample: Optional[List[np.ndarray]] = None

    # ------------------------------------------------------------- admit
    def submit(self, query, deadline: Optional[float] = None,
               arrival: Optional[float] = None) -> int:
        """Admit one request; returns its request id.

        ``deadline`` (clock timestamp) orders the admission queue
        (earliest first) and is reported as met/missed on respond.
        ``arrival`` defers the request's *visibility* to the engine —
        trace replay for staggered-arrival benchmarks; the admit
        timestamp is the arrival time, so queue time is measured from
        when the request actually arrived.

        Admission is guarded (DESIGN.md §6): an invalid query (empty,
        non-integer, negative ids, or a non-finite embedding row for an
        in-vocab token) or a full admission queue (``max_pending``)
        responds ``status='failed'`` with a reason — a rid is still
        returned and the response flows through the normal channel, so
        callers never need a second error path."""
        rid = next(self._rid)
        now = self.clock()
        t_arr = now if arrival is None else float(arrival)
        try:
            query = validate_query(query, self.sim)
        except QueryValidationError as e:
            return self._reject(rid, t_arr, now, f"invalid query: {e}",
                                kind="invalid")
        if self.max_pending is not None \
                and self.pending() >= self.max_pending:
            return self._reject(
                rid, t_arr, now,
                f"overloaded (admission queue at max_pending="
                f"{self.max_pending})", kind="overloaded")
        req = _Request(
            rid=rid, query=np.asarray(query, np.int32),
            trace=RequestTrace(rid=rid, t_admit=t_arr, deadline=deadline),
            arrival=t_arr, seq=next(self._seq))
        if t_arr > now:
            self._arrivals.append(req)
            self._arrivals.sort(key=lambda r: (r.arrival, r.seq))
        else:
            self._queue.append(req)
        return rid

    def _reject(self, rid: int, t_arr: float, now: float, reason: str,
                kind: str) -> int:
        """Refuse admission with an explicit ``failed`` response (never
        an exception, never a silent drop, never a garbage top-k)."""
        trace = RequestTrace(rid=rid, t_admit=t_arr, status="failed")
        trace.t_respond = now
        record(f"engine:{kind}")
        if kind == "overloaded":
            self.counters.observe_overload()
        else:
            self.counters.observe_invalid()
        self.counters.observe_respond(trace)
        self._completed.append(EngineResponse(
            rid=rid, result=_void_result(),
            latency_s=max(now - t_arr, 0.0), queue_s=0.0, waves=0,
            stream_hit=False, deadline_met=None, status="failed",
            reason=reason, epoch=self._epoch.epoch))
        return rid

    def _admit_arrived(self, now: float) -> None:
        while self._arrivals and self._arrivals[0].arrival <= now:
            self._queue.append(self._arrivals.pop(0))

    # -------------------------------------------------------------- join
    def _join(self, now: float) -> None:
        """Coalesce queued requests into the in-flight cohort: fetch or
        build their streams (one stacked sweep for all of a step's
        misses) and absorb them into the plan mid-flight."""
        room = self.max_wave_requests - len(self._inflight)
        if room <= 0 or not self._queue:
            return
        self._queue.sort(key=_Request.priority)
        joiners, self._queue = self._queue[:room], self._queue[room:]
        queries = [r.query for r in joiners]
        # per-request hit attribution: a duplicate of a query earlier in
        # the same join is served without a sweep too (matches the cache
        # counters' accounting of duplicate misses)
        hits, seen = [], set()
        for q in queries:
            key = self.stream_cache.key(q, self.params.alpha, self.sim)
            hits.append(self.stream_cache.contains(key) or key in seen)
            seen.add(key)
        streams = build_token_stream_batch_cached(
            queries, self.sim, self.params.alpha, self.stream_cache,
            use_kernel=self.params.stream_use_kernel)
        t_stream = self.clock()
        qis, new_tiles = self.plan.add_queries(queries)
        for t in new_tiles:
            self._tiles.setdefault(t.qi, {})[t.pi] = t
        self._streams.extend(streams)
        self._theta.extend([0.0] * len(joiners))
        for req, qi, hit in zip(joiners, qis, hits):
            req.qi = qi
            req.epoch = self._epoch.epoch
            req.pending = list(range(len(self.partitions)))
            req.trace.t_stream = t_stream
            req.trace.stream_hit = bool(hit)
            self._inflight[req.rid] = req

    # -------------------------------------------------------------- waves
    def _run_wave_tiles(self, tiles) -> None:
        if self._runner is not None:
            by_pi: Dict[int, list] = {}
            for t in tiles:
                by_pi.setdefault(t.pi, []).append(t)
            for pi in sorted(by_pi):
                run_fused_wave(self.plan, by_pi[pi], self._streams,
                               self._theta, self.pool, self.params,
                               self._runner)
        else:
            run_wave(self.plan, tiles, self._streams, self._theta,
                     self.pool, self.params)
        if self.bound_exchange is not None and self._inflight:
            # fold the mesh's all-reduce-max back into the live carries
            qis = [r.qi for r in self._inflight.values()]
            vec = _exchange(np.asarray([self._theta[qi] for qi in qis],
                                       np.float64), self.bound_exchange)
            for qi, v in zip(qis, vec):
                self._theta[qi] = max(self._theta[qi], float(v))

    def step(self) -> List[EngineResponse]:
        """One continuous-batching step: admit arrivals, shed the doomed
        (deadline already unreachable — BEFORE any wave tile is spent on
        them), join the queue, run one wave (a tile per live request at
        its next partition), respond to whoever finished.  Returns the
        step's responses.  Each step heartbeats into the attached
        :class:`FleetMonitor` (the router's health plane) and fires any
        :class:`FaultPlan` events addressed to this replica+step."""
        t_enter = self.clock()
        self._step_no += 1
        self._last_wave = 0
        verify_fault = False
        if self.fault_plan is not None:
            for ev in self.fault_plan.take(self.replica_id, self._step_no):
                if ev.kind == "crash":
                    raise ReplicaCrash(
                        f"replica {self.replica_id} crashed at engine "
                        f"step {self._step_no}")
                if ev.kind == "stall":
                    self._sleep(ev.stall_s)
                elif ev.kind == "verify_error":
                    verify_fault = True
        now = self.clock()
        self._admit_arrived(now)
        if self.shed_deadlines:
            self._shed_pass(now)
        depth = len(self._queue)
        # epoch rollout (DESIGN.md §6.5): behind the head epoch, the
        # in-flight cohort drains on its pinned snapshot and NO new
        # request joins — new admissions must see the committed epoch.
        # Resync happens at the first drained step boundary (immediately
        # for a standalone engine; when the router grants the rollout
        # slot for a fleet replica).
        if self.epoch_behind():
            if not self._inflight and self._resync_allowed:
                self.resync()
                self._join(now)
        else:
            self._join(now)
        if not self._inflight:
            self._heartbeat(t_enter)
            out, self._completed = self._completed, []
            return out

        wave, reqs = [], []
        for req in sorted(self._inflight.values(), key=_Request.priority):
            pi = req.pending.pop(0)
            tile = self._tiles[req.qi][pi]
            if req.trace.waves == 0:
                req.trace.t_first_wave = now
            req.trace.waves += 1
            wave.append(tile)
            reqs.append((req, pi))
        self.counters.observe_step(queue_depth=depth, wave_size=len(wave))
        self._last_wave = len(wave)
        if verify_fault:
            raise TransientVerifierError(
                f"replica {self.replica_id} verification fault at engine "
                f"step {self._step_no}")
        t_wave = self.clock()
        self._run_wave_tiles(wave)

        t_done = self.clock()
        dt = t_done - t_wave
        self._wave_ewma = (dt if self._wave_ewma == 0.0
                           else 0.5 * dt + 0.5 * self._wave_ewma)
        for req, pi in reqs:
            req.parts[pi] = self._tiles[req.qi][pi].result
            if not req.pending:
                self._respond(req, t_done)
        self._heartbeat(t_enter)
        out, self._completed = self._completed, []
        return out

    def _heartbeat(self, t_enter: float) -> None:
        if self.monitor is not None:
            self.monitor.heartbeat(self.replica_id, self._step_no,
                                   self.clock() - t_enter,
                                   epoch=self._epoch.epoch)

    # -------------------------------------------------------------- epoch
    @property
    def epoch(self) -> int:
        """The collection epoch this engine currently serves."""
        return self._epoch.epoch

    def epoch_behind(self) -> bool:
        """True when a commit installed a newer head epoch than the one
        this engine has pinned."""
        return self._epoch is not self.collection.head

    def resync(self) -> None:
        """Re-pin the head epoch at a step boundary: rebuild the plan /
        verifier pool over the new shard list, invalidate the stream
        cache's epoch key, release the old epoch's reader reference
        (the LAST reader out frees its exclusive device buffers), and
        re-warm the shard-local wave-config grid so the rollout does not
        recompile mid-traffic.  Requires a drained wave cohort — pinned
        in-flight requests NEVER migrate epochs (their bit-exactness is
        against the admission snapshot); queued requests join the new
        epoch on the very next step."""
        assert not self._inflight, "resync requires a drained wave cohort"
        old = self._epoch
        self._epoch = self.collection.pin()
        self.coll = self._epoch.coll
        self.partitions = self._epoch.shards
        self._streams, self._theta, self._tiles = [], [], {}
        self.plan = ExecutionPlan(self.partitions, [], pool_coll=self.coll,
                                  epoch=self._epoch.epoch)
        self.pool = VerifierPool(self.coll, self.sim, self.params)
        self.stream_cache.set_epoch(self._epoch.epoch)
        self.collection.release(old)
        record("engine:resync")
        self.counters.observe_resync()
        if self._warm_sample is not None:
            self._warmup_wave_grid(self._warm_sample)

    # ----------------------------------------------------------- shedding
    def _deadline_unreachable(self, req: _Request, now: float,
                              waves_left: int) -> bool:
        """True when even the optimistic service estimate (smoothed wave
        seconds x remaining partition waves) cannot meet the deadline.
        With no wave history yet the estimate is 0 — only requests whose
        deadline has ALREADY passed are shed (never a guess)."""
        d = req.trace.deadline
        return d is not None and now + self._wave_ewma * waves_left > d

    def _shed_pass(self, now: float) -> None:
        """Deadline-aware admission + wave sizing: shed doomed requests
        from the admission queue (before their stream is ever built) and
        from the in-flight cohort (before they occupy another tile of
        the wave being formed)."""
        waves_full = len(self.partitions)
        keep = []
        for req in self._queue:
            if self._deadline_unreachable(req, now, waves_full):
                self._shed(req, now, joined=False)
            else:
                keep.append(req)
        self._queue = keep
        for req in [r for r in self._inflight.values()
                    if self._deadline_unreachable(r, now, len(r.pending))]:
            self._shed(req, now, joined=True)

    def _shed(self, req: _Request, now: float, joined: bool) -> None:
        """Emit a ``status='shed'`` response without spending a wave tile
        (instrument event ``engine:shed`` is the audit trail)."""
        req.trace.t_respond = now
        req.trace.status = "shed"
        record("engine:shed")
        self.counters.observe_respond(req.trace)
        est = self._wave_ewma * (len(req.pending) if joined
                                 else len(self.partitions))
        self._completed.append(EngineResponse(
            rid=req.rid, result=_void_result(),
            latency_s=req.trace.latency_s, queue_s=max(req.trace.queue_s, 0.0),
            waves=req.trace.waves, stream_hit=req.trace.stream_hit,
            deadline_met=False, status="shed",
            reason=f"deadline unreachable (estimate {est:.4f}s, "
                   f"deadline {req.trace.deadline - now:+.4f}s away)",
            epoch=req.epoch if joined else self._epoch.epoch))
        if joined:
            self._retire(req)

    # ------------------------------------------------------------ respond
    def _respond(self, req: _Request, t_done: float) -> None:
        result = merge_topk([req.parts[pi] for pi in sorted(req.parts)],
                            self.params.k)
        req.trace.t_respond = t_done
        self.counters.observe_respond(req.trace)
        self._completed.append(EngineResponse(
            rid=req.rid, result=result,
            latency_s=req.trace.latency_s, queue_s=req.trace.queue_s,
            waves=req.trace.waves, stream_hit=req.trace.stream_hit,
            deadline_met=req.trace.deadline_met, epoch=req.epoch))
        self._retire(req)

    def _retire(self, req: _Request) -> None:
        """Release a joined request's plan/stream/tile state."""
        del self._inflight[req.rid]
        del self._tiles[req.qi]
        self._streams[req.qi] = None      # the LRU cache keeps the stream
        self._theta[req.qi] = 0.0
        remap = self.plan.retire_tiles([req.qi])
        if remap is not None:
            # the plan compacted its query ring (bounded plan size for
            # long-lived engines, DESIGN.md §9 item 9): shift every
            # qi-indexed engine structure through the same remap
            order = sorted(remap)        # old qis ascending == new order
            self._streams = [self._streams[old] for old in order]
            self._theta = [self._theta[old] for old in order]
            self._tiles = {remap[old]: tiles
                           for old, tiles in self._tiles.items()}
            for r in self._inflight.values():
                r.qi = remap[r.qi]

    # ---------------------------------------------------------- evacuate
    def evacuate(self) -> "tuple[List[EngineResponse], List[tuple]]":
        """Quarantine support (DESIGN.md §6): hand back everything this
        replica still owes — its buffered (already computed, still
        valid) responses plus a ``(rid, query, deadline)`` spec for
        every un-responded request — and reset all per-request state so
        the requests can be resubmitted elsewhere with no risk of a
        duplicate respond here.  Request-independent resources (stream
        cache, verifier pool, compiled wave programs, the borrowed
        collection) survive: a revived replica serves fresh requests
        immediately."""
        done, self._completed = self._completed, []
        pend = sorted(itertools.chain(self._arrivals, self._queue,
                                      self._inflight.values()),
                      key=lambda r: r.rid)
        specs = [(r.rid, r.query, r.trace.deadline) for r in pend]
        self._arrivals, self._queue = [], []
        self._inflight, self._tiles = {}, {}
        self._streams, self._theta = [], []
        self.plan = ExecutionPlan(self.partitions, [], pool_coll=self.coll,
                                  epoch=self._epoch.epoch)
        return done, specs

    # ------------------------------------------------------------- warmup
    def warmup(self, sample: Sequence[np.ndarray],
               reset_counters: bool = True) -> None:
        """Compile-warm the serving path before taking traffic.

        Serves pow2-sized cohorts of ``sample`` (stream sweep,
        refinement scan, solver, and wave shapes for every batch bucket
        the trace can coalesce), sweeps the SHARD-LOCAL fused wave-config
        grid (every shard x cohort bucket x the sample's pow2 event-chunk
        buckets plus a 2x guard bucket — steady-state queries landing one
        bucket above the sample still hit a compiled program), and sweeps
        the fused-verification pairwise pow2 grid, so steady-state
        serving — sharded or not — triggers zero recompiles
        (tests/test_recompile.py).  Standard request-engine startup
        practice; ``reset_counters`` wipes the warmup's traces from the
        metrics (the stream cache keeps its entries — that is warmup
        working as intended)."""
        sample = [np.asarray(q, np.int32) for q in sample]
        # kept for post-resync re-warm: a rollout re-sweeps the new
        # epoch's shard-local wave grid with the same sample
        self._warm_sample = sample if sample else None
        if sample:
            bs = 1
            while True:
                self.serve(sample[:bs])
                if bs >= len(sample):
                    break
                bs = min(2 * bs, len(sample))
            self._warmup_wave_grid(sample)
        # verification weight dispatch: the fused pairwise shape is
        # (pow2 rows, pow2 cols) — sweep the grid the pool can emit
        from ..core.postprocess import _pad_pow2
        q_hi = _pad_pow2(max((sum(len(q) for q in sample), 32)), 32)
        c_hi = min(VerifierPool._FUSE_TOKEN_CAP,
                   _pad_pow2(self.params.verify_batch
                             * max(int(self.coll.set_sizes.max()), 1)
                             * max(len(sample), 1), 256))
        qb = 32
        while qb <= q_hi:
            cb = 256
            while cb <= c_hi:
                self.sim.pairwise(np.zeros(qb, np.int32),
                                  np.zeros(cb, np.int32))
                cb *= 2
            qb *= 2
        if reset_counters:
            self.counters = EngineCounters()
            # scheduler-side counters (waves/rounds/...) are warmup work
            # too — reset them so summary() reflects only real traffic
            self.plan.stats = SchedulerStats(tiles=len(self.plan.tiles))

    def _warmup_wave_grid(self, sample: Sequence[np.ndarray]) -> None:
        """Sweep the shard-local fused wave-config grid (DESIGN.md §3.2).

        The serve() cohort sweep above compiles exactly the (shard,
        cohort-bucket, event-chunk-bucket) configs the SAMPLE's streams
        produce; live traffic with slightly heavier streams lands one
        pow2 chunk bucket up and would recompile mid-serve.  This pass
        walks the same doubling cohorts and, per shard, compiles the
        observed chunk bucket (an lru hit — free) plus its 2x guard
        bucket on an empty cohort (``WaveRunner.warm``), so every shard's
        near-neighborhood of the sample grid is compiled before traffic.
        Host-wave engines have no wave programs — nothing to do."""
        if self._runner is None:
            return
        from ..core.types import pow2
        from ..core.wave import _WAVE_CHUNK_GUARD
        streams = build_token_stream_batch_cached(
            sample, self.sim, self.params.alpha, self.stream_cache,
            use_kernel=self.params.stream_use_kernel)
        chunk = self.params.chunk_size
        counts = [s.inv.posting_counts() for s in self.partitions]
        bs = 1
        while True:
            cohort_q, cohort_s = sample[:bs], streams[:bs]
            B_pad = pow2(len(cohort_q))
            t_pad = pow2(max([len(s) for s in cohort_s] or [1]) or 1)
            nq_max = max(len(q) for q in cohort_q)
            nq_pad = pow2(max(nq_max, 1))
            q_words = pow2(max(1, -(-nq_max // 32)))
            for shard, cnt in zip(self.partitions, counts):
                buckets = set()
                for s in cohort_s:
                    n_events = int(cnt[s.token].sum())
                    if n_events:
                        buckets.add(pow2(max(1, -(-n_events // chunk))))
                for nc in sorted(b * g for b in buckets
                                 for g in _WAVE_CHUNK_GUARD):
                    self._runner.warm(shard, B_pad, nc, t_pad,
                                      nq_pad, q_words)
            if bs >= len(sample):
                break
            bs = min(2 * bs, len(sample))

    # -------------------------------------------------------------- drive
    def pending(self) -> int:
        """Requests anywhere in the lifecycle short of respond."""
        return len(self._arrivals) + len(self._queue) + len(self._inflight)

    def drain(self, max_idle_wait_s: float = 0.01) -> List[EngineResponse]:
        """Step until every submitted request (including future-dated
        arrivals) has responded.

        No busy-spin: an idle gap before a known future arrival sleeps
        the FULL gap in one call (arrivals are the only thing that can
        wake a single-threaded engine, so the historical 10ms-capped
        sleep just woke up ~100x/s to re-discover the same gap), and a
        step that moved nothing while in-flight work is still pending
        (a deferred/empty wave under shedding or fault injection) backs
        off exponentially, capped at ``max_idle_wait_s``."""
        out: List[EngineResponse] = []
        idle = max_idle_wait_s / 16
        while self.pending():
            n0 = len(out)
            out.extend(self.step())
            if len(out) > n0 or self._last_wave:
                idle = max_idle_wait_s / 16          # progress: reset
            elif self._inflight or self._queue:
                self._sleep(idle)                    # pending but stuck
                idle = min(2 * idle, max_idle_wait_s)
            elif self._arrivals:
                wait = self._arrivals[0].arrival - self.clock()
                if wait > 0:
                    self._sleep(wait)
        out.extend(self.step())           # flush any buffered responses
        return out

    def serve(self, queries: Sequence[np.ndarray],
              deadlines: Optional[Sequence[Optional[float]]] = None
              ) -> List[EngineResponse]:
        """Submit a batch and drain it; responses in request-id order."""
        for i, q in enumerate(queries):
            self.submit(q, deadline=deadlines[i] if deadlines else None)
        return sorted(self.drain(), key=lambda r: r.rid)

    def summary(self) -> dict:
        """Engine metrics incl. stream-cache and scheduler stats."""
        out = self.counters.summary(cache_stats=self.stream_cache.stats())
        out["schedule"] = self.schedule
        out["epoch"] = self.epoch
        out["scheduler"] = {
            "waves": self.plan.stats.waves,
            "rounds": self.plan.stats.rounds,
            "device_rounds": self.plan.stats.device_rounds,
            "fused_requests": self.plan.stats.fused_requests,
        }
        return out


@dataclasses.dataclass(frozen=True)
class RouterPolicy:
    """Failover knobs of the admission router (DESIGN.md §6).

    ``retry_budget`` bounds how many times one request may be
    resubmitted after its replica was quarantined (beyond it the
    request responds ``failed`` — never silently dropped);
    ``backoff_s`` is the base of the exponential resubmission delay
    (``backoff_s * 2**(attempt-1)``), so a flapping fleet is not
    hammered by the same request; ``revive_after_s`` is the quarantine
    cooldown after which a revivable (stalled / transient-error)
    replica rejoins the fleet — crashes are permanent."""

    retry_budget: int = 2
    backoff_s: float = 0.02
    revive_after_s: float = 0.25


class AdmissionRouter:
    """N :class:`RequestEngine` replicas over ONE logical collection
    behind a single front door (DESIGN.md §5), with a per-replica
    health plane (DESIGN.md §6).

    Every replica serves the SAME :class:`ShardedCollection` resource —
    per-shard device operands are uploaded once and borrowed by all, and
    identical (provider, params, mesh) triples share compiled wave
    programs through ``wave_runner_for`` — so a replica costs one plan +
    one verifier pool + one stream cache, not another copy of the
    repository.  The router admits requests with a global request id,
    routes each to the least-loaded HEALTHY replica (fewest
    lifecycle-pending requests; round-robin among ties, so an idle
    fleet still spreads arrivals), and merges responses back into
    global-rid order.

    Health: every engine step heartbeats into the shared
    :class:`FleetMonitor`.  A replica that raises, exceeds the
    straggler bound for ``FaultConfig.straggler_patience`` steps, or
    hangs past ``FaultConfig.heartbeat_timeout`` within one step is
    quarantined: its un-responded requests are evacuated and resubmitted
    to healthy replicas over the same shared collection (no re-upload),
    with a bounded retry budget and exponential backoff
    (:class:`RouterPolicy`).  A request served after failover responds
    ``status='retried'``; one that exhausts the budget (or finds no
    healthy replica) responds ``status='failed'`` with a reason —
    never an unhandled exception.  Global response ordering (sorted
    global rids) is preserved across failovers because a resubmitted
    request keeps its gid.

    Exactness is per replica — every SERVED response is bit-identical
    to a one-shot ``KoiosSearch.search_batch`` over the same
    collection, whether it was served first-try or after failover, so
    neither routing nor recovery can perturb any served result
    (tests/test_sharded_collection.py, tests/test_fault.py)."""

    def __init__(self, coll, sim_provider,
                 params: Optional[SearchParams] = None, replicas: int = 2,
                 partitions: int = 1, partition_by: str = "sets",
                 collection=None, policy: RouterPolicy = RouterPolicy(),
                 fault_config: FaultConfig = FaultConfig(),
                 fault_plan: Optional[FaultPlan] = None, **engine_kwargs):
        from .collection import ShardedCollection

        assert replicas >= 1, replicas
        if collection is None:
            collection = ShardedCollection.build(coll, partitions,
                                                 by=partition_by)
        self.collection = collection
        self.policy = policy
        self.monitor = FleetMonitor(
            replicas, fault_config,
            clock=engine_kwargs.get("clock", time.monotonic))
        self.engines = [
            RequestEngine(None, sim_provider, params,
                          collection=collection, monitor=self.monitor,
                          replica_id=ei, fault_plan=fault_plan,
                          **engine_kwargs)
            for ei in range(replicas)]
        self.clock = self.engines[0].clock       # shared trace clock
        self._sleep = self.engines[0]._sleep
        self._rid = itertools.count()
        self._local: Dict[int, "tuple[int, int]"] = {}  # gid -> (eng, rid)
        self._gid: Dict["tuple[int, int]", int] = {}    # inverse
        self._rr = itertools.count()                    # tie-break cursor
        # ---- health / failover state ----
        self._quarantined: Dict[int, dict] = {}   # ei -> {t, reason, ...}
        self._attempts: Dict[int, int] = {}       # gid -> resubmissions
        self._failed: List[EngineResponse] = []   # buffered failed resp.
        self.quarantine_log: List[dict] = []      # audit trail (soak)
        self.retries = 0                          # resubmissions issued
        self.failures = 0                         # failed responses
        self._t_last_recovered: Optional[float] = None

    # ------------------------------------------------------------- routing
    def healthy(self) -> List[int]:
        return [ei for ei in range(len(self.engines))
                if ei not in self._quarantined]

    def route(self) -> int:
        """Replica index for the next admit: least pending among HEALTHY
        replicas, round-robin among ties (deterministic under the
        injectable clocks); -1 when the whole fleet is quarantined."""
        healthy = self.healthy()
        if not healthy:
            return -1
        loads = [self.engines[ei].pending() for ei in healthy]
        lo = min(loads)
        ties = [ei for ei, n in zip(healthy, loads) if n == lo]
        return ties[next(self._rr) % len(ties)]

    def submit(self, query, deadline: Optional[float] = None,
               arrival: Optional[float] = None) -> int:
        """Admit one request to the fleet; returns its GLOBAL rid.  With
        every replica quarantined the request responds ``failed`` (with
        a reason) instead of raising."""
        gid = next(self._rid)
        ei = self.route()
        if ei < 0:
            self._fail(gid, "all replicas quarantined at admission")
            return gid
        rid = self.engines[ei].submit(query, deadline=deadline,
                                      arrival=arrival)
        self._local[gid] = (ei, rid)
        self._gid[(ei, rid)] = gid
        return gid

    def _globalize(self, ei: int,
                   responses: List[EngineResponse]
                   ) -> List[EngineResponse]:
        out = []
        for r in responses:
            gid = self._gid.pop((ei, r.rid))
            del self._local[gid]
            n = self._attempts.pop(gid, 0)
            if n and r.status == "ok":        # served after failover
                r = dataclasses.replace(r, status="retried", retries=n)
                self._t_last_recovered = self.clock()
            out.append(dataclasses.replace(r, rid=gid))
        return out

    # ----------------------------------------------------- fault handling
    def _fail(self, gid: int, reason: str) -> None:
        self.failures += 1
        self._failed.append(EngineResponse(
            rid=gid, result=_void_result(), latency_s=0.0, queue_s=0.0,
            waves=0, stream_hit=False, deadline_met=None,
            status="failed", retries=self._attempts.pop(gid, 0),
            reason=reason))

    def _quarantine(self, ei: int, reason: str,
                    revivable: bool) -> List[EngineResponse]:
        """Evict a replica and fail its requests over: buffered (already
        computed) responses are kept, every un-responded request is
        resubmitted to a healthy replica with exponential backoff —
        each exactly once, under the bounded retry budget."""
        now = self.clock()
        self._quarantined[ei] = {"t": now, "reason": reason,
                                 "revivable": revivable}
        self.quarantine_log.append({"t": now, "replica": ei,
                                    "reason": reason,
                                    "revivable": revivable})
        self.monitor.evict([ei])
        record("router:quarantine")
        done, specs = self.engines[ei].evacuate()
        out = self._globalize(ei, done)
        for rid, query, deadline in specs:
            gid = self._gid.pop((ei, rid))
            del self._local[gid]
            n = self._attempts.get(gid, 0) + 1
            self._attempts[gid] = n
            if n > self.policy.retry_budget:
                self._fail(gid, f"retry budget ({self.policy.retry_budget})"
                                f" exhausted; last replica {ei}: {reason}")
                continue
            nei = self.route()
            if nei < 0:
                self._fail(gid, f"no healthy replica left "
                                f"(replica {ei}: {reason})")
                continue
            delay = self.policy.backoff_s * (2 ** (n - 1))
            nrid = self.engines[nei].submit(
                query, deadline=deadline, arrival=self.clock() + delay)
            self._local[gid] = (nei, nrid)
            self._gid[(nei, nrid)] = gid
            self.retries += 1
            record("router:retry")
        return out

    def _maybe_revive(self) -> None:
        now = self.clock()
        for ei in [ei for ei, q in self._quarantined.items()
                   if q["revivable"]
                   and now - q["t"] >= self.policy.revive_after_s]:
            eng = self.engines[ei]
            if eng.epoch_behind():
                # a commit landed while the replica sat in quarantine:
                # it MUST resync to the head epoch before readmission
                # (its request state was evacuated, so the cohort is
                # drained by construction)
                eng.resync()
                record("router:revive_resync")
            del self._quarantined[ei]
            self.monitor.restore(ei)
            self.quarantine_log.append({"t": now, "replica": ei,
                                        "reason": "revived",
                                        "revivable": True})

    # ------------------------------------------------------- epoch rollout
    def _grant_rollout(self) -> None:
        """Serialize the epoch rollout replica-by-replica (DESIGN.md
        §6.5): exactly ONE behind healthy replica holds the resync grant
        at a time, so the fleet never loses more than one replica's
        serving capacity to a rebuild.  Behind replicas without the
        grant keep draining their pinned in-flight cohort but admit no
        new joins (new admissions must see the committed epoch).  The
        grantee with a drained cohort resyncs HERE — it may have no
        pending work, in which case the step loop would never reach
        it."""
        behind = [ei for ei in self.healthy()
                  if self.engines[ei].epoch_behind()]
        lead = behind[0] if behind else -1
        for ei in self.healthy():
            self.engines[ei]._resync_allowed = (not behind) or ei == lead
        if lead >= 0 and not self.engines[lead]._inflight:
            self.engines[lead].resync()
            record("router:rollout")

    # --------------------------------------------------------------- drive
    def pending(self) -> int:
        """Requests admitted but not yet responded (wherever they live —
        a replica's lifecycle or the failed buffer)."""
        return len(self._local) + len(self._failed)

    def step(self) -> List[EngineResponse]:
        """One fleet step: every healthy replica with work steps once
        (its own continuous-batching wave) under the health plane;
        responses come back with global rids, failures as ``failed``
        responses."""
        self._maybe_revive()
        self._grant_rollout()
        out: List[EngineResponse] = []
        timeout = self.monitor.cfg.heartbeat_timeout
        for ei, eng in enumerate(self.engines):
            # _completed counts too: a failed-at-submit response (over-
            # load / validation) buffers without ever becoming pending,
            # and only a step() flushes it
            if ei in self._quarantined \
                    or not (eng.pending() or eng._completed):
                continue
            t0 = self.clock()
            try:
                resp = eng.step()
            except ReplicaCrash as e:
                out.extend(self._quarantine(ei, str(e), revivable=False))
                continue
            except TransientVerifierError as e:
                out.extend(self._quarantine(ei, str(e), revivable=True))
                continue
            out.extend(self._globalize(ei, resp))
            if self.clock() - t0 > timeout:
                # the step eventually returned, but past the heartbeat
                # timeout — a concurrent monitor would have declared the
                # replica dead mid-step; quarantine it (its just-emitted
                # responses above are valid and kept)
                out.extend(self._quarantine(
                    ei, f"hung step ({self.clock() - t0:.3f}s > "
                        f"heartbeat timeout {timeout}s)", revivable=True))
        for ei in self.monitor.stragglers():
            if ei not in self._quarantined:
                out.extend(self._quarantine(
                    ei, "straggler (step latency over "
                        f"{self.monitor.cfg.straggler_factor}x fleet "
                        "median)", revivable=True))
        out.extend(self._failed)
        self._failed = []
        return out

    def drain(self) -> List[EngineResponse]:
        """Step until every admitted request has responded (ok, shed,
        retried, or failed).  Idle gaps — backoff resubmissions or
        future-dated arrivals — sleep to the earliest arrival across the
        fleet; a quarantine cooldown sleeps in ``revive_after_s`` hops."""
        out: List[EngineResponse] = []
        while self.pending():
            n0 = len(out)
            for e in self.engines:    # so _last_wave reflects THIS pass
                e._last_wave = 0      # (skipped engines keep it stale)
            out.extend(self.step())
            if len(out) > n0 or any(e._last_wave for e in self.engines):
                continue                          # progress was made
            waits = [e._arrivals[0].arrival - self.clock()
                     for e in self.engines if e._arrivals]
            if any(e._inflight or e._queue for e in self.engines):
                continue                          # work ready next step
            if waits:
                self._sleep(max(min(waits), 0.0))
            elif self._quarantined:
                self._sleep(self.policy.revive_after_s)
            else:                                 # defensive: never spin
                self._sleep(0.001)
        for ei, eng in enumerate(self.engines):     # flush buffered
            if ei not in self._quarantined:
                out.extend(self._globalize(ei, eng.step()))
        return out

    def serve(self, queries: Sequence[np.ndarray],
              deadlines: Optional[Sequence[Optional[float]]] = None
              ) -> List[EngineResponse]:
        """Submit a batch across the fleet and drain it; responses in
        global request-id (= submission) order."""
        for i, q in enumerate(queries):
            self.submit(q, deadline=deadlines[i] if deadlines else None)
        return sorted(self.drain(), key=lambda r: r.rid)

    def warmup(self, sample: Sequence[np.ndarray],
               reset_counters: bool = True) -> None:
        """Warm every replica.  Compiled programs (waves, scans, solvers)
        are process-global, so replica 0 pays the compiles and the rest
        sweep compile-free — but each replica still primes its own
        stream cache and shape buckets."""
        for eng in self.engines:
            eng.warmup(sample, reset_counters=reset_counters)

    def summary(self) -> dict:
        """Fleet metrics: per-replica summaries + fleet totals, plus the
        health plane's failover accounting (DESIGN.md §6)."""
        from .instrument import _quantile

        per = [e.summary() for e in self.engines]
        lats = sorted(t.latency_s for e in self.engines
                      for t in e.counters.traces if t.status == "ok")
        return {
            "replicas": len(self.engines),
            "healthy_replicas": len(self.healthy()),
            "epoch": self.collection.epoch,
            "replica_epochs": [e.epoch for e in self.engines],
            "collection": self.collection.describe(),
            "requests": sum(p["requests"] for p in per),
            "shed": sum(p["shed"] for p in per),
            "retries": self.retries,
            "failed": self.failures,
            "quarantines": len([q for q in self.quarantine_log
                                if q["reason"] != "revived"]),
            "p50_latency_s": _quantile(lats, 0.50),
            "p99_latency_s": _quantile(lats, 0.99),
            "waves": sum(p["scheduler"]["waves"] for p in per),
            "per_replica": per,
        }
