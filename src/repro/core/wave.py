"""On-device fused wave execution (DESIGN.md §3, fused wave program).

One device program per partition *wave* — the partition's (query x
partition) tiles for the whole request batch — chaining what the overlap
schedule round-trips through the host (DESIGN.md §9 item 6, resolved):

  Stage A0 device-resident event expansion (DESIGN.md §3.3): the wave
           consumes the COMPACT token stream — (token, q, sim) tuples,
           uploaded once per plan since streams are partition-
           independent — and expands it to posting-level events
           *in-trace* through the partition's device-resident CSR
           inverted index (``InvertedIndex.device_arrays``, uploaded
           once per index lifetime), a searchsorted-on-cumsum gather
           mirroring ``token_stream.expand_to_events`` bit for bit.
           This kills the per-tile host expansion and the event-array
           host->device transfer — the largest remaining per-wave
           upload (events outnumber tuples by the mean posting length);
  Stage A  all K refinement chunk scans (`lax.scan` over the shared
           (carry, chunk) -> carry step from ``core.refinement``, set-
           segmented admission with in-trace within-set ranks, vmapped
           over the wave's queries);
  Stage B  candidate compaction by prefix-sum mask
           (``kernels.refine_verify.compact_indices``);
  Stage C  theta_lb update + on-device bound exchange
           (``runtime.sharding.all_reduce_max_traced`` — `lax.pmax`
           over the repository shard axes, identity without a mesh);
  Stage D  the first R auction/Hungarian verification rounds with
           Lemma-8 dual-bound aborts, mirroring one
           ``PostprocessState.next_request``/``apply`` cycle per round
           (top-ub batch selection, weight recompute on the normalized
           table, bracket application, UB-filter drops), with a bound
           exchange after every round.

Waves chain through a donated theta carry: wave p+1 consumes wave p's
on-device theta output, so the scheduler dispatches every wave before
materializing any (JAX async dispatch) and the host sees device data
exactly once per wave.  The host drive loop then resumes from
``PostprocessState.from_wave`` for whatever verification the R device
rounds did not finish — the host path stays the bit-identical oracle.

Exactness does not depend on the wave reproducing the host trajectory:
every device step only ever (a) raises certified lower bounds, (b) drops
candidates whose certified upper bound is strictly below such a bound, or
(c) records certified [lb, ub] brackets (ambiguous auction brackets are
resolved exactly on device, mirroring the pool's Hungarian fallback), so
any schedule of these steps yields the same final top-k — the same
invariant that makes overlap == sequential (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ref import event_ranks_ref
from ..kernels.refine_verify import candidate_weights, compact_indices
from ..runtime import instrument
from ..runtime.sharding import _round_down_f32, all_reduce_max_traced
from .matching.auction import _auction_single, make_eps_schedule
from .matching.hungarian import _hungarian_padded
from .refinement import (refine_carry_init, refine_chunk_step,
                         refine_finalize)
from .types import SearchParams
from .types import pow2 as _pow2

_NEGINF = jnp.float32(-jnp.inf)


def expand_events_traced(tok, qp, sm, indptr, posting_set, posting_slot,
                         n_chunks: int, chunk: int):
    """Device-resident event expansion (DESIGN.md §3.3): one query's
    compact stream tuples -> padded event chunks, in-trace.

    The searchsorted-on-cumsum gather mirror of
    ``token_stream.expand_to_events`` + ``pad_events``, bit for bit:
    ``reps[i]`` postings per tuple, event e produced by the tuple whose
    cumulative posting count first exceeds e, posting picked by the
    within-tuple offset.  ``tok`` pads with -1 (zero postings);
    ``posting_set``/``posting_slot`` carry one trailing sentinel entry
    (-1 / 0) that every pad event's clipped gather hits, and pad sims
    repeat the final real sim (0.0 for an empty expansion) — exactly
    the host pad semantics.  Returns (set, q, slot, sim) arrays of
    shape (n_chunks, chunk).
    """
    E_pad = n_chunks * chunk
    t_pad = tok.shape[0]
    n_post = posting_set.shape[0] - 1            # trailing sentinel
    reps = jnp.where(tok >= 0, indptr[tok + 1] - indptr[tok], 0)
    ends = jnp.cumsum(reps)                      # event offset per tuple
    total = ends[-1]
    iota = jnp.arange(E_pad, dtype=jnp.int32)
    ti = jnp.minimum(jnp.searchsorted(ends, iota, side="right"), t_pad - 1)
    valid = iota < total
    tokc = jnp.maximum(tok[ti], 0)
    gather = jnp.where(valid,
                       indptr[tokc] + (iota - (ends[ti] - reps[ti])),
                       n_post)
    set_id = posting_set[gather]
    slot = posting_slot[gather]
    q = jnp.where(valid, qp[ti], 0)
    last_ti = jnp.minimum(
        jnp.searchsorted(ends, jnp.maximum(total - 1, 0), side="right"),
        t_pad - 1)
    last_sim = jnp.where(total > 0, sm[last_ti], jnp.float32(0.0))
    sim = jnp.where(valid, sm[ti], last_sim)
    return (set_id.reshape(n_chunks, chunk), q.reshape(n_chunks, chunk),
            slot.reshape(n_chunks, chunk), sim.reshape(n_chunks, chunk))


def fused_available(params: SearchParams, sim_provider) -> bool:
    """Whether the fused schedule can run here (else: overlap fallback).

    Requires a dense cosine embedding-table provider (the wave recomputes
    verification weights on-device from the normalized table) and either
    a TPU backend or an explicit opt-in to Pallas interpret mode
    (``params.fused == 'interpret'`` — tests/CI off-TPU)."""
    if params.fused == "off":
        return False
    if getattr(sim_provider, "name", None) != "cosine":
        return False
    if getattr(sim_provider, "table", None) is None:
        return False
    if jax.default_backend() == "tpu":
        return True
    return params.fused == "interpret"


class WaveConfig(NamedTuple):
    """Static (shape/mode) parameters of one wave program — the jit key."""

    num_sets: int
    total_slots: int
    q_words: int
    k: int
    n_chunks: int
    chunk: int
    n_tuples: int                    # pow2 stream-tuple pad (Stage A0 input)
    nq_pad: int
    c_pad: int
    B: int
    verify_batch: int
    rounds: int
    ub_mode: str
    verifier: str
    refine_layout: str
    alpha: float
    interpret: bool
    use_kernel: bool
    max_rounds: int = 5000


def _masked_kth(x, mask, k: int):
    """k-th largest of ``x`` where ``mask``; 0.0 when fewer than k entries
    are masked in — the device mirror of ``postprocess._kth``."""
    if k > x.shape[0]:
        return jnp.float32(0.0)
    vals = jnp.where(mask, x, _NEGINF)
    kth = jax.lax.top_k(vals, k)[0][k - 1]
    return jnp.where(jnp.sum(mask) >= k, kth, jnp.float32(0.0))


# Cap on the wave's per-round verification batch.  The device rounds'
# vmapped solver runs all (B x vb) padded rows in lockstep — rows with no
# pending candidate are nq=0-cheap but still march through the batch's max
# trip count — so oversized round batches cost more than the saved host
# round-trips buy (CPU interpret A/B: 16 beats 32 by ~1.3x at the opendata
# P=4 preset).  The host continuation drains whatever the capped rounds
# leave, so the cap never affects results, only the device/host split.
_WAVE_VB_CAP = 16

# Warmup guard multipliers over the sample-observed pow2 chunk buckets:
# each observed bucket is warmed (x1 — an lru hit after the cohort sweep)
# together with the next bucket up (x2), so live streams one pow2 step
# heavier than the warmup sample still hit a compiled program.
_WAVE_CHUNK_GUARD = (1, 2)


@functools.lru_cache(maxsize=None)
def _wave_fn(cfg: WaveConfig, mesh):
    """Build (and cache) the jitted wave program for one static config.

    The theta carry (argument 6) is donated: waves chain through it, so
    XLA reuses one buffer for the whole plan's bound vector."""
    alpha = jnp.float32(cfg.alpha)
    vb = min(cfg.verify_batch, cfg.num_sets)

    def one_round(lb, ub, live, verified, th, qt, nq, table_n, set_tok,
                  sizes32, eps):
        """One verification round for one query — the jittable mirror of
        PostprocessState.next_request + VerifierPool.verify_requests +
        PostprocessState.apply (DESIGN.md §3)."""
        # -- filter pass (theta refresh, UB filter, No-EM, batch pick) --
        th = jnp.maximum(th, _masked_kth(lb, live, cfg.k))
        drop = live & (ub < th)
        n_drop = jnp.sum(drop & ~verified)
        live = live & ~drop
        theta_ub = _masked_kth(ub, live, cfg.k)
        no_em = live & ~verified & (lb >= theta_ub)
        need = live & ~verified & (ub > th) & ~no_em
        _, sel = jax.lax.top_k(jnp.where(need, ub, _NEGINF), vb)
        valid = jnp.take(need, sel)

        # -- weights: same per-entry math as the host pool (bit-equal) --
        toks = set_tok[sel]
        ncs_b = jnp.where(valid, sizes32[sel], 0)
        w = candidate_weights(table_n, qt, toks, sizes32[sel], nq, alpha)
        nqs_b = jnp.where(valid, nq, 0)
        th_b = jnp.where(valid, th, _NEGINF)

        # -- solve (Lemma-8 dual aborts) --
        if cfg.verifier == "hungarian":
            so, _ = jax.vmap(_hungarian_padded)(w, nqs_b, ncs_b)
            out_lb, out_ub = so, so
            early = jnp.zeros((vb,), bool)
            settle = valid                   # exact: every row settles
            n_early = jnp.int32(0)
            n_full = jnp.sum(valid)
        else:
            a_lb, a_ub, _, early, _ = jax.vmap(
                lambda wi, ni, ci, ti: _auction_single(
                    wi, ni, ci, eps, ti, cfg.max_rounds,
                    use_kernel=cfg.use_kernel))(w, nqs_b, ncs_b, th_b)
            # A bracket that straddles theta (or, in hybrid mode, any
            # non-degenerate bracket) is NOT settled here: its row keeps
            # the tightened bracket but stays unverified, so the host
            # continuation re-verifies it with the pool's exact fallback.
            # Paying a vmapped exact solve on-device for every row would
            # forfeit the auction's entire advantage (DESIGN.md §9 item 4)
            # in the common no-ambiguity case.
            amb = (~early) & (a_lb < th_b) & (a_ub > th_b)
            if cfg.verifier == "hybrid":
                amb = amb | ((~early) & (a_ub - a_lb > 1e-6))
            out_lb = a_lb
            out_ub = jnp.maximum(a_ub, a_lb)
            early = early & valid
            settle = valid & ~amb
            n_early = jnp.sum(early)
            n_full = jnp.sum(valid & ~early & ~amb)

        # -- apply (dense one-hot fold: no duplicate-index scatters) --
        # brackets fold in for every solved row (tightening is always
        # sound); only settled rows flip to verified
        sets_iota = jnp.arange(cfg.num_sets)
        mark = valid[:, None] & (sets_iota[None, :] == sel[:, None])
        applied = jnp.any(mark, axis=0)
        upd_lb = jnp.max(jnp.where(mark, out_lb[:, None], _NEGINF), axis=0)
        upd_ub = jnp.min(jnp.where(mark, out_ub[:, None],
                                   jnp.float32(jnp.inf)), axis=0)
        lb = jnp.where(applied, jnp.maximum(lb, upd_lb), lb)
        ub = jnp.where(applied, jnp.minimum(ub, upd_ub), ub)
        verified = verified | jnp.any(mark & settle[:, None], axis=0)
        dead = jnp.any(mark & early[:, None], axis=0)
        live = live & ~dead
        return lb, ub, live, verified, th, n_drop, n_early, n_full

    def fn(st_tok, st_q, st_sim, qtok, nqs, theta, table_n,
           set_tok, set_sizes, eps, indptr, posting_set, posting_slot):
        sizes32 = set_sizes.astype(jnp.int32)

        # ---- Stage A: K refinement chunk scans, vmapped over the wave ----
        # (each begins with Stage A0, the in-trace event expansion)
        def refine(tok, qp, sm, nq):
            chunks = expand_events_traced(
                tok, qp, sm, indptr, posting_set, posting_slot,
                cfg.n_chunks, cfg.chunk)
            if cfg.refine_layout == "segmented":
                # within-set ranks per chunk (the set-segmented layout's
                # level index), computed in-trace — lane compaction is
                # host-only (data-dependent widths), so the wave runs
                # the flat masked-level form of the same scan
                chunks = chunks + (jax.vmap(event_ranks_ref)(chunks[0]),)
            cap = jnp.minimum(sizes32, nq)
            st0 = refine_carry_init(cfg.num_sets, cfg.q_words,
                                    cfg.total_slots)
            st, killed = jax.lax.scan(
                lambda s, c: refine_chunk_step(s, c, cap, cfg.k,
                                               cfg.ub_mode,
                                               layout=cfg.refine_layout),
                st0, chunks)
            S, ub, seen, alive, th, killed_f = refine_finalize(
                st, cap, alpha, cfg.k, cfg.ub_mode)
            return S, ub, seen, alive, th, jnp.sum(killed) + killed_f

        S, ub0, seen, alive, th_ref, pruned_ref = jax.vmap(refine)(
            st_tok, st_q, st_sim, nqs)

        # ---- Stage B: candidate compaction (prefix-sum mask kernel) ----
        surv = seen & alive
        surv_idx, surv_cnt = jax.vmap(
            lambda m: compact_indices(m, interpret=cfg.interpret))(surv)

        # ---- Stage C: theta update + on-device bound exchange ----
        theta = jnp.maximum(theta, th_ref)
        theta = all_reduce_max_traced(theta, mesh)

        # ---- Stage D: first R verification rounds ----
        lb, ub, live = S, ub0, surv
        verified = jnp.zeros_like(surv)
        zeros = jnp.zeros((cfg.B,), jnp.int32)

        def round_step(carry, _):
            lb, ub, live, verified, theta, c_post, c_early, c_full = carry
            lb, ub, live, verified, th_q, dp, de, df = jax.vmap(
                lambda l, u, lv, vf, t, q, n: one_round(
                    l, u, lv, vf, t, q, n, table_n, set_tok, sizes32, eps)
            )(lb, ub, live, verified, theta, qtok, nqs)
            theta = all_reduce_max_traced(th_q, mesh)
            return (lb, ub, live, verified, theta,
                    c_post + dp, c_early + de, c_full + df), None

        (lb, ub, live, verified, theta, c_post, c_early, c_full), _ = \
            jax.lax.scan(round_step,
                         (lb, ub, live, verified, theta,
                          zeros, zeros, zeros),
                         None, length=cfg.rounds)

        return (surv_idx, surv_cnt, lb, ub, live, verified,
                jnp.sum(seen, axis=1), pruned_ref,
                c_post, c_early, c_full, theta)

    return jax.jit(fn, donate_argnums=(5,))


# Engine-lifetime runner reuse (DESIGN.md §3.2): keyed by provider/mesh
# identity + the full (hashable, frozen) params.  Bounded in practice by
# the handful of provider/params combinations a process serves; entries
# hold only the eps schedule and the compiled-program cache key — ALL
# collection device state (CSR triplets, dense operands, the normalized
# table) lives on the ShardedCollection's shards and is merely borrowed
# at launch, so every runner/engine/replica over one collection shares
# one copy of everything.
_RUNNER_CACHE: dict = {}


def wave_runner_for(sim_provider, params: SearchParams,
                    mesh=None) -> "WaveRunner":
    """The shared :class:`WaveRunner` of a (provider, params, mesh)
    triple — cross-request reuse of the eps schedule and compiled wave
    programs; collection operands are borrowed per-shard at launch."""
    key = (id(sim_provider), params, id(mesh))
    hit = _RUNNER_CACHE.get(key)
    if hit is None:
        # pin the provider (and mesh) so their ids cannot be recycled by
        # the allocator while the cache entry is alive
        hit = _RUNNER_CACHE[key] = (
            WaveRunner(sim_provider, params, mesh=mesh), sim_provider, mesh)
    return hit[0]


@dataclasses.dataclass
class _TileMeta:
    """Host-side per-tile stream facts (stats; not part of the program)."""

    empty: bool
    n_tuples: int = 0
    n_events: int = 0
    n_chunks: int = 0


@dataclasses.dataclass(frozen=True)
class StreamOperands:
    """Device-resident compact stream input of a plan's waves (§3.3):
    stacked (B_pad, T_pad) stream tuples + query tokens/lengths, built
    once per plan and shared by every partition wave."""

    tok: object                      # (B_pad, T_pad) int32, -1 pad
    q_pos: object                    # (B_pad, T_pad) int32
    sim: object                      # (B_pad, T_pad) float32
    qtok: object                     # (B_pad, nq_pad) int32, -1 pad
    nqs: object                      # (B_pad,) int32
    n_tuples: int                    # T_pad (pow2)
    nq_pad: int
    q_words: int
    _placed: dict = dataclasses.field(default_factory=dict, repr=False)

    def on(self, device) -> "StreamOperands":
        """This operand set committed to ``device`` (placed-shard waves;
        one copy per device per plan, cached).  ``device=None`` is the
        unplaced identity — the degenerate single-place case."""
        if device is None:
            return self
        hit = self._placed.get(device)
        if hit is None:
            import jax

            instrument.record(f"h2d:stream_upload[{device.id}]")
            hit = self._placed[device] = dataclasses.replace(
                self, tok=jax.device_put(self.tok, device),
                q_pos=jax.device_put(self.q_pos, device),
                sim=jax.device_put(self.sim, device),
                qtok=jax.device_put(self.qtok, device),
                nqs=jax.device_put(self.nqs, device), _placed={})
        return hit


@dataclasses.dataclass
class WaveLaunch:
    """An in-flight wave: device outputs + per-tile metadata."""

    out: tuple                       # device arrays (async)
    tile_meta: List[_TileMeta]
    cfg: WaveConfig


@dataclasses.dataclass
class WaveOutputs:
    surv_idx: np.ndarray             # (B, num_sets) int32, -1 padded
    surv_cnt: np.ndarray             # (B,)
    lb: np.ndarray                   # (B, num_sets) f32
    ub: np.ndarray
    live: np.ndarray                 # (B, num_sets) bool
    verified: np.ndarray
    candidates: np.ndarray           # (B,) int32
    pruned_ref: np.ndarray
    pruned_post: np.ndarray
    em_early: np.ndarray
    em_full: np.ndarray


class WaveRunner:
    """Fused-wave context: eps schedule, compiled-program reuse, theta
    chaining.  Collection device state is NOT owned here: every launch
    *borrows* the shard's CSR triplet / dense operands / normalized
    table through the :class:`~repro.runtime.collection.Shard` accessors
    — the ShardedCollection resource is the single owner, so N engines,
    replicas, and one-shot searches over one collection share one upload
    of everything (DESIGN.md §5).

    The runner holds no per-plan state — every launch threads its carry
    explicitly — so ONE runner serves every plan/request that shares a
    (provider, params, mesh) triple; obtain it via
    :func:`wave_runner_for` (the request engine and the fused schedule
    both do)."""

    def __init__(self, sim_provider, params: SearchParams,
                 mesh=None):
        self.params = params
        self.mesh = mesh
        self.interpret = jax.default_backend() != "tpu"
        self.sim = sim_provider
        self.eps = make_eps_schedule(params.auction_eps)

    def init_theta(self, theta0: np.ndarray, B_pad: int):
        t = np.zeros(B_pad, np.float32)
        t[:len(theta0)] = _round_down_f32(theta0)
        return jnp.asarray(t)

    # ------------------------------------------------------------- streams
    def stream_operands(self, queries: Sequence[np.ndarray], streams,
                        B_pad: int) -> "StreamOperands":
        """Upload the wave input ONCE per plan: the compact stacked
        stream tuples plus query tokens/lengths.  Streams (and queries)
        are partition-independent, so every wave of a plan shares these
        device arrays — with the device-resident index expansion
        (§3.3) this is the only per-plan host->device payload, replacing
        the per-wave event-array uploads (events outnumber tuples by
        the mean posting length)."""
        t_pad = _pow2(max([len(s) for s in streams] or [1]) or 1)
        nq_max = max([len(q) for q in queries] or [1])
        nq_pad = _pow2(max(nq_max, 1))
        st_tok = np.full((B_pad, t_pad), -1, np.int32)
        st_q = np.zeros((B_pad, t_pad), np.int32)
        st_sim = np.zeros((B_pad, t_pad), np.float32)
        qtok = np.full((B_pad, nq_pad), -1, np.int32)
        nqs = np.zeros(B_pad, np.int32)
        for qi, (q, s) in enumerate(zip(queries, streams)):
            st_tok[qi, :len(s)] = s.token
            st_q[qi, :len(s)] = s.q_pos
            st_sim[qi, :len(s)] = s.sim
            qtok[qi, :len(q)] = q
            nqs[qi] = len(q)
        instrument.record("h2d:stream_upload")
        return StreamOperands(
            tok=jnp.asarray(st_tok), q_pos=jnp.asarray(st_q),
            sim=jnp.asarray(st_sim), qtok=jnp.asarray(qtok),
            nqs=jnp.asarray(nqs), n_tuples=t_pad, nq_pad=nq_pad,
            q_words=_pow2(max(1, -(-nq_max // 32))))

    # -------------------------------------------------------------- warmup
    def warm(self, index, B_pad: int, n_chunks: int, n_tuples: int,
             nq_pad: int, q_words: int) -> None:
        """Compile one shard-local wave config by running it on an empty
        (all-pad) cohort — the engine warmup's shard grid sweep
        (DESIGN.md §3.2): steady-state traffic whose pow2 buckets were
        warmed here reuses the compiled program, so sharded serving
        keeps the zero-recompile invariant.  Empty streams expand to
        zero events, so the run itself is cheap and touches no result
        state; already-compiled configs are lru-cache hits."""
        set_tok, sizes32, c_pad = index.wave_operands()
        indptr_dev, pset_dev, pslot_dev = index.csr_arrays()
        table_n = index.table_for(self.sim)
        put = getattr(index, "_put", jnp.asarray)
        cfg = WaveConfig(
            num_sets=index.coll.num_sets,
            total_slots=index.coll.total_tokens, q_words=q_words,
            k=self.params.k, n_chunks=n_chunks,
            chunk=self.params.chunk_size, n_tuples=n_tuples,
            nq_pad=nq_pad, c_pad=c_pad, B=B_pad,
            verify_batch=min(self.params.verify_batch, _WAVE_VB_CAP),
            rounds=self.params.wave_rounds, ub_mode=self.params.ub_mode,
            verifier=self.params.verifier,
            refine_layout=self.params.refine_layout,
            alpha=float(self.params.alpha),
            interpret=self.interpret, use_kernel=not self.interpret)
        _wave_fn(cfg, self.mesh)(
            put(np.full((B_pad, n_tuples), -1, np.int32)),
            put(np.zeros((B_pad, n_tuples), np.int32)),
            put(np.zeros((B_pad, n_tuples), np.float32)),
            put(np.full((B_pad, nq_pad), -1, np.int32)),
            put(np.zeros(B_pad, np.int32)),
            put(np.zeros(B_pad, np.float32)),
            table_n, set_tok, sizes32, self.eps,
            indptr_dev, pset_dev, pslot_dev)

    # -------------------------------------------------------------- launch
    def launch_wave(self, index, queries: Sequence[np.ndarray], streams,
                    theta_dev,
                    stream_ops: "Optional[StreamOperands]" = None
                    ) -> "tuple[WaveLaunch, object]":
        """Dispatch one partition wave; returns (launch, chained theta).

        Nothing is materialized here — JAX async dispatch lets the next
        wave queue behind this one on-device while the host sizes and
        dispatches later waves.  The only per-wave host work left is
        counting each tile's events from the host CSR counts (to size
        the pow2 chunk grid); expansion itself runs in-trace from
        ``stream_ops`` (built here when the caller didn't share one
        across waves) and the shard's borrowed CSR arrays.

        ``index`` is a :class:`~repro.runtime.collection.Shard`: its
        CSR triplet, dense operands, and normalized table are borrowed
        views owned by the ShardedCollection.  When the shard is PLACED
        the wave runs on its device: the shared stream operands get a
        per-device committed copy and the theta carry hops to the
        shard's device — that hop IS the cross-shard bound exchange of
        the carry-chained drive (an on-device all-reduce via the mesh is
        the alternative exchange mode; placed shards use the carry
        chain).  Unplaced shards take the identical code path with
        every placement a no-op — the degenerate single-device case."""
        set_tok, sizes32, c_pad = index.wave_operands()
        indptr_dev, pset_dev, pslot_dev = index.csr_arrays()
        table_n = index.table_for(self.sim)
        coll = index.coll
        B_pad = theta_dev.shape[0]
        chunk = self.params.chunk_size
        if stream_ops is None:
            stream_ops = self.stream_operands(queries, streams, B_pad)
        device = getattr(index, "device", None)
        if device is not None:
            stream_ops = stream_ops.on(device)
            if theta_dev.devices() != {device}:
                # the theta_lb carry hops shard-to-shard: the bound
                # raised on any earlier shard re-prunes this one
                instrument.record(f"h2d:theta_hop[s{index.sid}]")
            theta_dev = jax.device_put(theta_dev, device)

        counts = index.inv.posting_counts()
        metas: List[_TileMeta] = []
        for qi, q in enumerate(queries):
            s = streams[qi]
            n_events = int(counts[s.token].sum())
            if n_events == 0:
                metas.append(_TileMeta(empty=True))
                continue
            metas.append(_TileMeta(
                empty=False, n_tuples=len(s), n_events=n_events,
                n_chunks=_pow2(max(1, -(-n_events // chunk)))))
        n_max = max([m.n_chunks for m in metas if not m.empty] or [1])

        cfg = WaveConfig(
            num_sets=coll.num_sets, total_slots=coll.total_tokens,
            q_words=stream_ops.q_words, k=self.params.k, n_chunks=n_max,
            chunk=chunk, n_tuples=stream_ops.n_tuples,
            nq_pad=stream_ops.nq_pad, c_pad=c_pad, B=B_pad,
            verify_batch=min(self.params.verify_batch, _WAVE_VB_CAP),
            rounds=self.params.wave_rounds, ub_mode=self.params.ub_mode,
            verifier=self.params.verifier,
            refine_layout=self.params.refine_layout,
            alpha=float(self.params.alpha),
            interpret=self.interpret, use_kernel=not self.interpret)
        fn = _wave_fn(cfg, self.mesh)
        instrument.record(f"h2d:wave_dispatch[s{getattr(index, 'sid', 0)}]")
        out = fn(stream_ops.tok, stream_ops.q_pos, stream_ops.sim,
                 stream_ops.qtok, stream_ops.nqs, theta_dev,
                 table_n, set_tok, sizes32, self.eps,
                 indptr_dev, pset_dev, pslot_dev)
        return WaveLaunch(out=out, tile_meta=metas, cfg=cfg), out[-1]

    # --------------------------------------------------------- materialize
    def materialize(self, launch: WaveLaunch) -> WaveOutputs:
        """One blocking device->host transfer per wave.  The trailing
        theta output is NOT read — it was donated into the next wave's
        program (the carry chain) and only the final wave's copy survives
        (the scheduler reads that one directly)."""
        instrument.record("d2h:wave_materialize")
        vals = [np.asarray(x) for x in launch.out[:-1]]
        return WaveOutputs(*vals)
