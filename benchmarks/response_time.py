"""Paper Table III: response time + memory, KOIOS vs Baseline/Baseline+.

Also covers the SilkMoth comparison mode (--sim ngram): the same engine
with character n-gram Jaccard similarity (KOIOS is similarity-agnostic —
§VIII-B).

Batched-serving A/B (``--batched`` / ``--per-query``): times the fused
multi-query pipeline (``search_partition_batch``) against the per-query
loop on the same query batch, asserting identical top-k results:

    PYTHONPATH=src python -m benchmarks.response_time --batched

Scale-out A/B (``--partitions N --overlap``): times the overlapped
partition scheduler (async refinement dispatch, global verify queue,
bidirectional theta_lb feedback) against the sequential running-max
partition loop, asserting bit-identical results:

    PYTHONPATH=src python -m benchmarks.response_time --partitions 4 --overlap

Fused-wave A/B (``--fused``): times the on-device wave schedule (one
device program per partition wave — refinement chunk scans + compaction +
the first R verification rounds fused, DESIGN.md §3) against the
host-driven overlap schedule, counting host<->device dispatches/transfers
with ``repro.runtime.instrument`` and asserting bit-identical results:

    PYTHONPATH=src python -m benchmarks.response_time --fused --partitions 4

Request-engine A/B (``--engine``): replays a staggered-arrival trace
through the continuous-batching engine (admission queue, mid-flight
joins, LRU stream cache — DESIGN.md §3.2) against the per-batch serving
loop that waits for each fixed batch to fill, comparing TRUE mean
per-request (admit->respond) latency and asserting hash-identical
results:

    PYTHONPATH=src python -m benchmarks.response_time --engine --partitions 4

Sharded-collection A/B (``--shards N`` / ``--sharded``): builds the same
logical repository as a 1-shard and an N-shard
:class:`~repro.runtime.collection.ShardedCollection` (``--place`` pins
shard i round-robin to ``jax.devices()[i]``), runs the fused schedule +
device-side top-k merge tree over both, asserts bit-identical results
(equal hash), and attributes per-shard wave dispatches / uploads /
theta-carry hops from the sid-tagged instrument event stream:

    PYTHONPATH=src python -m benchmarks.response_time --shards 4 --place

Every A/B invocation also merges its record into
``BENCH_response_time.json`` under ``records[<mode>]`` (per-mode
latencies + a hash of the results) so CI accumulates the perf
trajectory of every mode as one artifact; ``--json ''`` disables.
"""
from __future__ import annotations

import argparse
import hashlib
import json

import numpy as np

from repro.core import (NGramJaccardSimilarity, SearchParams,
                        baseline_plus_topk, baseline_topk, search_partition,
                        search_partition_batch)
from repro.data import sample_queries

from .common import index_for, memory_footprint_bytes, timed, world


def _ngram_incidence(vocab_size: int, dim: int = 512, seed: int = 0):
    """Hashed 3-gram incidence stand-in (tokens are synthetic ids; we hash
    pseudo-spellings)."""
    rng = np.random.default_rng(seed)
    inc = np.zeros((vocab_size, dim), np.float32)
    for t in range(vocab_size):
        g = rng.integers(0, dim, size=6)      # ~6 3-grams per token
        inc[t, g] = 1.0
    return inc


def run(datasets=("dblp", "opendata", "twitter", "wdc"), n_queries=2,
        k=10, alpha=0.8, sim_kind="cosine", include_baseline=True):
    rows = []
    params = SearchParams(k=k, alpha=alpha)
    for ds in datasets:
        coll, sim = world(ds)
        if sim_kind == "ngram":
            sim = NGramJaccardSimilarity(_ngram_incidence(coll.vocab_size))
        index = index_for(ds)
        queries = sample_queries(coll, n_queries, seed=11)
        # warm the jit caches (the paper's timings exclude setup; pow2
        # padding makes later queries reuse these compilations)
        if queries:
            search_partition(index, queries[0], sim, params)
            if include_baseline:
                baseline_topk(index, queries[0], sim, params)
        tk = tb = tbp = 0.0
        match_k = match_b = 0
        for q in queries:
            rk, dt = timed(search_partition, index, q, sim, params)
            tk += dt
            match_k += rk.stats.exact_matches
            if include_baseline:
                rb, dt = timed(baseline_topk, index, q, sim, params)
                tb += dt
                match_b += rb.stats.exact_matches
                rbp, dt = timed(baseline_plus_topk, index, q, sim, params)
                tbp += dt
                # sanity: identical score multisets
                assert np.allclose(np.sort(rk.lb), np.sort(rb.lb), atol=1e-3)
        n = max(len(queries), 1)
        mem = memory_footprint_bytes(ds, int(np.mean(
            [len(q) for q in queries])) if queries else 1)
        rows.append({
            "dataset": ds, "sim": sim_kind, "queries": n,
            "koios_s": tk / n,
            "baseline_s": tb / n if include_baseline else None,
            "baseline_plus_s": tbp / n if include_baseline else None,
            "speedup": (tb / tk) if include_baseline and tk else None,
            "em_koios": match_k / n,
            "em_baseline": match_b / n if include_baseline else None,
            "mem_mb": mem["total"] / 1e6,
        })
    return rows


def run_ab(dataset="opendata", batch_size=8, k=10, alpha=0.8,
           verifier="hungarian", repeats=3):
    """Batched vs per-query A/B on one query batch; identical-results check.

    Both paths are warmed (jit caches), then each is timed ``repeats``
    times over the same ``batch_size`` queries; reports mean seconds per
    query and the batched-path speedup.
    """
    params = SearchParams(k=k, alpha=alpha, verifier=verifier)
    _, sim = world(dataset)
    index = index_for(dataset)
    queries = sample_queries(index.coll, batch_size, seed=11)
    zeros = [0.0] * len(queries)

    def per_query():
        return [search_partition(index, q, sim, params) for q in queries]

    def batched():
        return search_partition_batch(index, queries, sim, params, zeros)

    r_pq, _ = timed(per_query)       # warm both paths before timing
    r_b, _ = timed(batched)
    for a, b in zip(r_pq, r_b):
        assert np.array_equal(a.ids, b.ids) and np.array_equal(a.lb, b.lb), \
            "batched path diverged from per-query results"

    t_pq = min(timed(per_query)[1] for _ in range(repeats))
    t_b = min(timed(batched)[1] for _ in range(repeats))
    n = len(queries)
    return {
        "dataset": dataset, "batch_size": n, "verifier": verifier,
        "per_query_s": t_pq / n, "batched_s": t_b / n,
        "speedup": t_pq / t_b if t_b else float("inf"),
        "result_hash": result_hash(r_b),
        "identical_topk": True,
    }


def run_partition_ab(dataset="opendata", partitions=4, batch_size=8, k=10,
                     alpha=0.8, verifier="hungarian", repeats=3):
    """Overlapped scheduler vs sequential partition loop at P partitions.

    Both arms run the same engine (same plan decomposition, same shared
    verifier pool); the A/B isolates the scheduler's drive order —
    overlapped refinement dispatch + the global cross-partition queue +
    bidirectional theta_lb feedback vs the pre-scheduler running-max host
    loop.  Results are asserted bit-identical; reports mean seconds per
    query and the overlap speedup.
    """
    from repro.core import KoiosSearch

    params = SearchParams(k=k, alpha=alpha, verifier=verifier)
    coll, sim = world(dataset)
    engine = KoiosSearch(coll, sim, params, partitions=partitions)
    queries = sample_queries(coll, batch_size, seed=11)

    def sequential():
        return engine.search_batch(queries, schedule="sequential")

    def overlap():
        return engine.search_batch(queries, schedule="overlap")

    r_seq, _ = timed(sequential)     # warm both paths before timing
    r_ovl, _ = timed(overlap)
    st = engine.scheduler_stats
    for a, b in zip(r_seq, r_ovl):
        assert np.array_equal(a.ids, b.ids) and np.array_equal(a.lb, b.lb), \
            "overlapped schedule diverged from the sequential partition loop"

    t_seq = min(timed(sequential)[1] for _ in range(repeats))
    t_ovl = min(timed(overlap)[1] for _ in range(repeats))
    n = len(queries)
    return {
        "dataset": dataset, "partitions": partitions, "batch_size": n,
        "verifier": verifier,
        "sequential_s": t_seq / n, "overlap_s": t_ovl / n,
        "speedup": t_seq / t_ovl if t_ovl else float("inf"),
        "bound_raises": st.bound_raises,
        "backward_raises": st.backward_raises,
        "result_hash": result_hash(r_ovl),
        "identical_topk": True,
    }


def result_hash(results) -> str:
    """Stable digest of a list of SearchResults (ids + score bits)."""
    h = hashlib.sha256()
    for r in results:
        h.update(np.ascontiguousarray(r.ids).tobytes())
        h.update(np.ascontiguousarray(r.lb).tobytes())
    return h.hexdigest()[:16]


def run_fused_ab(dataset="opendata", partitions=4, batch_size=8, k=10,
                 alpha=0.8, verifier="hungarian", repeats=7):
    """Fused on-device wave schedule vs host-driven overlap at P partitions.

    Both arms run the identical plan decomposition; the A/B isolates what
    the wave program eliminates — per-tile refinement dispatch +
    materialization and the first R rounds' pairwise/solver round-trips.
    Host<->device dispatches and transfers are counted via
    ``repro.runtime.instrument``; results are asserted bit-identical."""
    import jax

    from repro.core import KoiosSearch
    from repro.runtime import instrument

    fused_mode = "auto" if jax.default_backend() == "tpu" else "interpret"
    params = SearchParams(k=k, alpha=alpha, verifier=verifier,
                          fused=fused_mode)
    coll, sim = world(dataset)
    engine = KoiosSearch(coll, sim, params, partitions=partitions)
    queries = sample_queries(coll, batch_size, seed=11)

    def overlap():
        return engine.search_batch(queries, schedule="overlap")

    def fused():
        return engine.search_batch(queries, schedule="fused")

    r_ovl, _ = timed(overlap)        # warm both paths before timing
    r_fus, _ = timed(fused)
    assert engine.scheduler_stats.schedule == "fused", \
        "fused schedule unavailable (provider or backend gate)"
    for a, b in zip(r_ovl, r_fus):
        assert np.array_equal(a.ids, b.ids) and np.array_equal(a.lb, b.lb), \
            "fused wave schedule diverged from the overlap schedule"

    counts = {}
    for name, fn in (("overlap", overlap), ("fused", fused)):
        with instrument.counting() as c:
            fn()
        counts[name] = instrument.totals(c)
    t_ovl = min(timed(overlap)[1] for _ in range(repeats))
    t_fus = min(timed(fused)[1] for _ in range(repeats))
    n = len(queries)
    st = engine.scheduler_stats
    return {
        "dataset": dataset, "partitions": partitions, "batch_size": n,
        "verifier": verifier,
        "overlap_s": t_ovl / n, "fused_s": t_fus / n,
        "speedup": t_ovl / t_fus if t_fus else float("inf"),
        "overlap_transfers": counts["overlap"]["total"],
        "fused_transfers": counts["fused"]["total"],
        "waves": st.waves, "device_rounds": st.device_rounds,
        "result_hash": result_hash(r_fus),
        "identical_topk": True,
    }


def run_sharded_ab(dataset="opendata", shards=4, batch_size=8, k=10,
                   alpha=0.8, verifier="hungarian", repeats=3,
                   place=False):
    """Sharded collection resource vs the 1-shard reference repository.

    Builds the SAME logical repository twice as a
    :class:`~repro.runtime.collection.ShardedCollection` — once at one
    shard (the degenerate reference) and once at ``shards`` contiguous
    set ranges, optionally placed round-robin over ``jax.devices()``
    (``--place``).  Both arms run the fused wave schedule and the
    device-side top-k merge tree; results are asserted bit-identical
    (equal ``result_hash``), and per-shard wave dispatches / uploads /
    theta-carry hops are attributed via the sid-tagged event stream of
    ``repro.runtime.instrument``."""
    import jax

    from repro.core import KoiosSearch
    from repro.runtime import instrument
    from repro.runtime.collection import ShardedCollection

    fused_mode = "auto" if jax.default_backend() == "tpu" else "interpret"
    params = SearchParams(k=k, alpha=alpha, verifier=verifier,
                          fused=fused_mode)
    coll, sim = world(dataset)
    devices = jax.devices() if place else None
    reference = KoiosSearch(None, sim, params,
                            collection=ShardedCollection.build(coll, 1))
    sharded = KoiosSearch(
        None, sim, params,
        collection=ShardedCollection.build(coll, shards, devices=devices))
    queries = sample_queries(coll, batch_size, seed=11)

    def one_shard():
        return reference.search_batch(queries, schedule="fused")

    def n_shard():
        return sharded.search_batch(queries, schedule="fused")

    with instrument.counting() as c_cold:    # first borrow = the uploads
        r_sh, _ = timed(n_shard)
    r_ref, _ = timed(one_shard)
    assert sharded.scheduler_stats.schedule == "fused", \
        "fused schedule unavailable (provider or backend gate)"
    for a, b in zip(r_ref, r_sh):
        assert np.array_equal(a.ids, b.ids) and np.array_equal(a.lb, b.lb), \
            "sharded collection diverged from the 1-shard reference"
    ref_hash, sh_hash = result_hash(r_ref), result_hash(r_sh)
    assert ref_hash == sh_hash, "result hash diverged across shard counts"

    counts = {}
    for name, fn in (("one_shard", one_shard), ("sharded", n_shard)):
        with instrument.counting() as c:
            fn()
        counts[name] = instrument.totals(c)
    with instrument.counting() as c_warm:    # steady-state sharded arm
        n_shard()

    def per_shard(counter):
        """sid-tagged events grouped per shard: {'s0': {tag: n}, ...}."""
        out = {}
        for tag, n in sorted(counter.items()):
            if "[s" not in tag:
                continue
            site, sid = tag.rsplit("[", 1)
            out.setdefault(sid.rstrip("]"), {})[site] = n
        return out

    t_ref = min(timed(one_shard)[1] for _ in range(repeats))
    t_sh = min(timed(n_shard)[1] for _ in range(repeats))
    n = len(queries)
    desc = sharded.collection.describe()
    return {
        "dataset": dataset, "shards": sharded.collection.num_shards,
        "batch_size": n, "verifier": verifier,
        "placed": sharded.collection.placed,
        "devices": len(set(s["device"] for s in desc["shards"]
                           if s["device"])),
        "one_shard_s": t_ref / n, "sharded_s": t_sh / n,
        "speedup": t_ref / t_sh if t_sh else float("inf"),
        "one_shard_transfers": counts["one_shard"]["total"],
        "sharded_transfers": counts["sharded"]["total"],
        "upload_events": per_shard(c_cold),
        "steady_state_events": per_shard(c_warm),
        "shard_sets": [s["sets"] for s in desc["shards"]],
        "device_bytes": desc["device_bytes"],
        "result_hash": sh_hash,
        "identical_topk": True,
    }


def run_engine_ab(dataset="opendata", partitions=4, batch_size=8,
                  n_requests=16, unique=8, stagger_ms=25.0, k=10,
                  alpha=0.8, verifier="hungarian", repeats=3):
    """Continuous-batching engine vs the per-batch serving loop under a
    staggered-arrival trace.

    Both arms see the same trace: request i arrives ``stagger_ms`` after
    request i-1, and requests repeat each of ``unique`` distinct queries
    (the stream-cache story).  The baseline is the pre-engine serving
    loop — wait until a fixed ``batch_size`` batch has fully arrived,
    run it one-shot, repeat — so every request's latency includes its
    wait for the batch to fill.  The engine admits each request on
    arrival and coalesces whatever is queued into the next wave
    (mid-flight joins).  Mean per-request (admit->respond) latency is
    the headline; results are asserted hash-identical across both arms
    and the warmed one-shot reference."""
    import time as _time

    from repro.core import KoiosSearch
    from repro.runtime.engine import RequestEngine

    params = SearchParams(k=k, alpha=alpha, verifier=verifier)
    coll, sim = world(dataset)
    one_shot = KoiosSearch(coll, sim, params, partitions=partitions)
    indexes = one_shot.partitions       # engines reuse the same indexes

    base = sample_queries(coll, unique, seed=11)
    reqs = [base[i % unique] for i in range(n_requests)]
    stagger = stagger_ms / 1e3

    # Warm both paths' jit caches and pin the reference results.  The
    # engine's steady-state shapes depend on cohort size (pow2-padded
    # solver rows), so warm every pow2 cohort the staggered trace can
    # coalesce — after this, the sweep itself compiles nothing
    # (tests/test_recompile.py asserts the same invariant).
    ref = one_shot.search_batch(reqs, schedule="overlap")
    warm_engine = RequestEngine(coll, sim, params, indexes=indexes)
    warm_engine.warmup(reqs)
    for r, a in zip(warm_engine.serve(reqs), ref):
        assert np.array_equal(r.result.ids, a.ids) \
            and np.array_equal(r.result.lb, a.lb), \
            "engine diverged from the one-shot path"
    ref_hash = result_hash(ref)

    def engine_run():
        eng = RequestEngine(coll, sim, params, indexes=indexes)
        t0 = eng.clock()
        for i, q in enumerate(reqs):
            eng.submit(q, arrival=t0 + i * stagger)
        resp = sorted(eng.drain(), key=lambda r: r.rid)
        return eng, [r.result for r in resp], [r.latency_s for r in resp]

    def loop_run():
        results, lats = [], []
        t0 = _time.monotonic()
        arrivals = [i * stagger for i in range(n_requests)]
        for lo in range(0, n_requests, batch_size):
            hi = min(lo + batch_size, n_requests)
            wait = (t0 + arrivals[hi - 1]) - _time.monotonic()
            if wait > 0:                 # batch waits for its last member
                _time.sleep(wait)
            rs = one_shot.search_batch(reqs[lo:hi], schedule="overlap")
            t_done = _time.monotonic()
            results.extend(rs)
            lats.extend(t_done - (t0 + arrivals[i])
                        for i in range(lo, hi))
        return results, lats

    eng_means, loop_means = [], []
    eng = None
    for _ in range(repeats):
        eng, eng_results, eng_lats = engine_run()
        loop_results, loop_lats = loop_run()
        assert result_hash(eng_results) == ref_hash, \
            "engine results diverged under the staggered trace"
        assert result_hash(loop_results) == ref_hash
        eng_means.append(sum(eng_lats) / len(eng_lats))
        loop_means.append(sum(loop_lats) / len(loop_lats))
    t_eng, t_loop = min(eng_means), min(loop_means)
    summary = eng.summary()
    return {
        "dataset": dataset, "partitions": partitions,
        "batch_size": batch_size, "n_requests": n_requests,
        "unique_queries": unique, "stagger_ms": stagger_ms,
        "verifier": verifier,
        "engine_s": t_eng, "batch_loop_s": t_loop,
        "speedup": t_loop / t_eng if t_eng else float("inf"),
        "cache_hit_rate": summary["stream_cache"]["hit_rate"],
        "mean_queue_depth": summary["mean_queue_depth"],
        "engine_waves": summary["scheduler"]["waves"],
        "result_hash": ref_hash,
        "identical_topk": True,
    }


def write_bench_json(record: dict, path: str, mode: str) -> None:
    """BENCH_response_time.json — the perf-trajectory artifact CI uploads.

    One document keyed by mode: each A/B invocation merges its record
    under ``records[mode]`` instead of clobbering the file, so the
    trajectory of every mode (``batched_ab``/``partition_ab``/
    ``fused_ab``/``engine_ab``/``sharded_ab``/``suite``) stays
    comparable across PRs.
    Legacy single-mode documents are migrated on first merge."""
    if not path:
        return
    doc = {"benchmark": "response_time", "records": {}}
    try:
        with open(path) as f:
            prev = json.load(f)
        if "records" in prev:
            doc["records"] = prev["records"]
        elif prev.get("mode"):           # legacy single-mode layout
            legacy = {k: v for k, v in prev.items()
                      if k not in ("benchmark", "mode")}
            doc["records"][prev["mode"]] = legacy
    except (OSError, ValueError):
        pass
    doc["records"][mode] = record
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path} (mode={mode}, "
          f"{len(doc['records'])} records)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--batched", action="store_true",
                      help="A/B the fused multi-query path (headline row)")
    mode.add_argument("--per-query", action="store_true",
                      help="A/B with the per-query loop as the headline row")
    mode.add_argument("--overlap", action="store_true",
                      help="A/B the overlapped partition scheduler vs the "
                           "sequential partition loop (use --partitions)")
    mode.add_argument("--fused", action="store_true",
                      help="A/B the fused on-device wave schedule vs the "
                           "overlap schedule (use --partitions; interpret "
                           "mode off-TPU)")
    mode.add_argument("--engine", action="store_true",
                      help="A/B the continuous-batching request engine vs "
                           "the per-batch serving loop under a staggered-"
                           "arrival trace (true per-request latencies, "
                           "stream-cache hit rate)")
    mode.add_argument("--sharded", action="store_true",
                      help="A/B the sharded collection resource vs the "
                           "1-shard reference repository (bit-identical "
                           "top-k, per-shard transfer attribution; "
                           "implied by --shards)")
    ap.add_argument("--dataset", default=None,
                    help="restrict to one dataset (A/B default: opendata; "
                         "table mode default: all four)")
    ap.add_argument("--batch-size", type=int, default=8,
                    help="A/B modes only")
    ap.add_argument("--partitions", type=int, default=4,
                    help="--overlap A/B only: repository partition count")
    ap.add_argument("--shards", type=int, default=None,
                    help="shard count for the sharded-collection A/B "
                         "(selects --sharded mode; default 4)")
    ap.add_argument("--place", action="store_true",
                    help="--sharded A/B only: pin shard i round-robin "
                         "to jax.devices()[i] (theta carry hops "
                         "device-to-device)")
    ap.add_argument("--n-requests", type=int, default=16,
                    help="--engine A/B only: trace length")
    ap.add_argument("--stagger-ms", type=float, default=25.0,
                    help="--engine A/B only: inter-arrival gap")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--verifier", default="hungarian",
                    choices=["hungarian", "auction", "hybrid"],
                    help="A/B modes only")
    ap.add_argument("--json", default="BENCH_response_time.json",
                    help="perf-artifact path for A/B modes ('' disables)")
    args = ap.parse_args(argv)

    if args.sharded or args.shards is not None:
        r = run_sharded_ab(args.dataset or "opendata",
                           args.shards or 4, args.batch_size,
                           k=args.k, verifier=args.verifier,
                           place=args.place)
        print("dataset,arm,shards,devices,batch_size,"
              "mean_latency_per_query_s,speedup_vs_one_shard,"
              "transfers,result_hash,identical_topk")
        for name, shards, lat, sp, tr in (
                ("sharded", r["shards"], r["sharded_s"], r["speedup"],
                 r["sharded_transfers"]),
                ("one-shard", 1, r["one_shard_s"], 1.0,
                 r["one_shard_transfers"])):
            print(f"{r['dataset']},{name},{shards},{r['devices']},"
                  f"{r['batch_size']},{lat:.4f},{sp:.2f},{tr},"
                  f"{r['result_hash']},{r['identical_topk']}")
        for sid in sorted(r["upload_events"]):
            up = r["upload_events"][sid]
            steady = r["steady_state_events"].get(sid, {})
            print(f"  [{sid}] uploads={ {t.split(':', 1)[1]: n for t, n in up.items()} } "
                  f"steady_waves={steady.get('h2d:wave_dispatch', 0)} "
                  f"theta_hops={steady.get('h2d:theta_hop', 0)}")
        write_bench_json(r, args.json, "sharded_ab")
        return 0

    if args.engine:
        r = run_engine_ab(args.dataset or "opendata", args.partitions,
                          args.batch_size, n_requests=args.n_requests,
                          stagger_ms=args.stagger_ms, k=args.k,
                          verifier=args.verifier)
        print("dataset,mode,partitions,n_requests,stagger_ms,"
              "mean_latency_per_request_s,speedup_vs_batch_loop,"
              "cache_hit_rate,mean_queue_depth,result_hash,identical_topk")
        for name, lat, sp in (
                ("engine", r["engine_s"], r["speedup"]),
                ("batch-loop", r["batch_loop_s"], 1.0)):
            print(f"{r['dataset']},{name},{r['partitions']},"
                  f"{r['n_requests']},{r['stagger_ms']},{lat:.4f},"
                  f"{sp:.2f},{r['cache_hit_rate']:.2f},"
                  f"{r['mean_queue_depth']:.1f},{r['result_hash']},"
                  f"{r['identical_topk']}")
        write_bench_json(r, args.json, "engine_ab")
        assert r["engine_s"] < r["batch_loop_s"], \
            "engine must beat the per-batch loop on mean latency " \
            "under a staggered trace"
        return 0

    if args.fused:
        r = run_fused_ab(args.dataset or "opendata", args.partitions,
                         args.batch_size, k=args.k,
                         verifier=args.verifier)
        print("dataset,schedule,partitions,batch_size,"
              "mean_latency_per_query_s,speedup_vs_overlap,"
              "transfers,waves,device_rounds,result_hash,identical_topk")
        for name, lat, sp, tr in (
                ("fused", r["fused_s"], r["speedup"],
                 r["fused_transfers"]),
                ("overlap", r["overlap_s"], 1.0, r["overlap_transfers"])):
            print(f"{r['dataset']},{name},{r['partitions']},"
                  f"{r['batch_size']},{lat:.4f},{sp:.2f},{tr},"
                  f"{r['waves']},{r['device_rounds']},"
                  f"{r['result_hash']},{r['identical_topk']}")
        write_bench_json({
            "modes": {
                "fused": {"mean_latency_per_query_s": r["fused_s"],
                          "transfers": r["fused_transfers"]},
                "overlap": {"mean_latency_per_query_s": r["overlap_s"],
                            "transfers": r["overlap_transfers"]},
            },
            "speedup": r["speedup"], "result_hash": r["result_hash"],
            "dataset": r["dataset"], "partitions": r["partitions"],
            "batch_size": r["batch_size"], "verifier": r["verifier"],
        }, args.json, "fused_ab")
        assert r["fused_transfers"] < r["overlap_transfers"], \
            "fused wave must reduce host<->device transfers"
        return 0

    if args.overlap:
        r = run_partition_ab(args.dataset or "opendata", args.partitions,
                             args.batch_size, k=args.k,
                             verifier=args.verifier)
        print("dataset,schedule,partitions,batch_size,"
              "mean_latency_per_query_s,speedup_vs_sequential,"
              "bound_raises,backward_raises,identical_topk")
        for name, lat, sp in (("overlap", r["overlap_s"], r["speedup"]),
                              ("sequential", r["sequential_s"], 1.0)):
            print(f"{r['dataset']},{name},{r['partitions']},"
                  f"{r['batch_size']},{lat:.4f},{sp:.2f},"
                  f"{r['bound_raises']},{r['backward_raises']},"
                  f"{r['identical_topk']}")
        write_bench_json({
            "modes": {
                "overlap": {"mean_latency_per_query_s": r["overlap_s"]},
                "sequential": {
                    "mean_latency_per_query_s": r["sequential_s"]},
            },
            "speedup": r["speedup"], "result_hash": r["result_hash"],
            "dataset": r["dataset"], "partitions": r["partitions"],
            "batch_size": r["batch_size"], "verifier": r["verifier"],
        }, args.json, "partition_ab")
        return 0

    if args.batched or args.per_query:
        r = run_ab(args.dataset or "opendata", args.batch_size, k=args.k,
                   verifier=args.verifier)
        print("dataset,mode,batch_size,mean_latency_per_query_s,"
              "speedup_vs_per_query,identical_topk")
        rows = [("batched", r["batched_s"], r["speedup"]),
                ("per-query", r["per_query_s"], 1.0)]
        if args.per_query:
            rows.reverse()
        for mode_name, lat, sp in rows:
            print(f"{r['dataset']},{mode_name},{r['batch_size']},"
                  f"{lat:.4f},{sp:.2f},{r['identical_topk']}")
        write_bench_json({
            "modes": {
                "batched": {"mean_latency_per_query_s": r["batched_s"]},
                "per_query": {
                    "mean_latency_per_query_s": r["per_query_s"]},
            },
            "speedup": r["speedup"], "result_hash": r["result_hash"],
            "dataset": r["dataset"], "batch_size": r["batch_size"],
            "verifier": r["verifier"],
        }, args.json, "batched_ab")
        return 0

    table_kw = {"k": args.k}
    if args.dataset:
        table_kw["datasets"] = (args.dataset,)
    print("dataset,sim,koios_s,baseline_s,baseline+_s,speedup,"
          "em_koios,em_baseline,mem_mb")
    for r in run(**table_kw):
        print(f"{r['dataset']},{r['sim']},{r['koios_s']:.2f},"
              f"{r['baseline_s']:.2f},{r['baseline_plus_s']:.2f},"
              f"{r['speedup']:.1f},{r['em_koios']:.0f},"
              f"{r['em_baseline']:.0f},{r['mem_mb']:.1f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
