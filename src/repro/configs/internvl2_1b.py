"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B backbone.

Assigned: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
[arXiv:2404.16821; hf]

Per the assignment the modality frontend is a STUB: input_specs() provides
precomputed ViT patch embeddings (batch, 1024, d_model) prepended to the
text tokens; loss/logits cover the text region."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm", num_layers=24, d_model=896,
    num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151655,
    tie_embeddings=True, frontend="vision", frontend_len=1024)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        tie_embeddings=True, frontend="vision", frontend_len=8,
        dtype="float32", remat="none")
