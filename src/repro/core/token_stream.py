"""The token stream I_e — chunked, blocked-matmul replacement for Faiss+PQ.

Paper §IV: I_e yields (q, t, sim(q, t)) tuples for every vocabulary token t
with sim >= alpha to some query element, in globally descending similarity
order, realised with a Faiss index plus a |Q|-slot priority queue.

TPU adaptation (DESIGN.md §2): the index probe is a blocked similarity
matmul (MXU) over vocabulary tiles — `repro.kernels.cosine_topk` is the
fused Pallas kernel for the serving path; here the same block computation
runs through the jnp provider and the >=alpha entries are compacted host
side (compaction is inherently dynamic-shape, i.e. host work in either
implementation — the paper also walks its priority queue on the host).

The refinement phase consumes the stream *expanded to posting-level events*
through the inverted index (paper: "probing I_s"), still in descending
order:  (set, q, slot, sim) per posting of each streamed token.

Multi-query serving: :func:`build_token_stream_batch` stacks B queries into
one (sum |Q_b| x |V|) blocked sweep — one provider dispatch and one host
compaction per vocab block for the whole batch — and returns per-query
streams bit-identical to B single-query calls.

A stream depends only on (query, provider, alpha) — NOT on the partition —
so the partition scheduler (``repro.core.scheduler``) builds each query's
stream once and expands it through every partition's inverted index,
replacing the historical per-partition rebuild with P calls to
:func:`expand_to_events` per query.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .inverted_index import InvertedIndex
from .types import SetCollection


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """All pairs (q position, token, sim >= alpha), descending by sim."""

    q_pos: np.ndarray    # (T,) int32 — position of the query element in Q
    token: np.ndarray    # (T,) int32 — vocabulary token id
    sim: np.ndarray      # (T,) float32, non-increasing

    def __len__(self) -> int:
        return len(self.sim)


@dataclasses.dataclass(frozen=True)
class EventStream:
    """Posting-level expansion of a TokenStream (still descending by sim)."""

    set_id: np.ndarray   # (E,) int32
    q_pos: np.ndarray    # (E,) int32
    slot: np.ndarray     # (E,) int64 — flat token-array slot (t-side identity)
    sim: np.ndarray      # (E,) float32, non-increasing
    n_tuples: int        # stream tuples that produced these events

    def __len__(self) -> int:
        return len(self.sim)


def _finalize_stream(query: np.ndarray, q_pos: np.ndarray, token: np.ndarray,
                     sim: np.ndarray, vocab: int) -> TokenStream:
    """Identity-pair completion + global descending sort for one query."""
    nq = len(query)
    # Identity pairs (q, q, 1.0) — add any that the provider missed (e.g.
    # degenerate embeddings) and dedupe.
    in_vocab = query < vocab
    id_q = np.arange(nq, dtype=np.int32)[in_vocab]
    id_t = query[in_vocab]
    key = q_pos.astype(np.int64) * vocab + token
    id_key = id_q.astype(np.int64) * vocab + id_t
    missing = ~np.isin(id_key, key)
    q_pos = np.concatenate([q_pos, id_q[missing]])
    token = np.concatenate([token, id_t[missing]])
    sim = np.concatenate([sim, np.ones(missing.sum(), np.float32)])

    # identity pairs must carry sim exactly 1.0 even if the provider returned
    # a slightly different value
    ident = query[q_pos] == token
    sim = np.where(ident, np.float32(1.0), sim)

    order = np.argsort(-sim, kind="stable")
    return TokenStream(q_pos=q_pos[order], token=token[order], sim=sim[order])


def _build_stream_entries_kernel(stacked: np.ndarray, sim_provider,
                                 alpha: float, block_size: int):
    """(row, token, sim >= alpha) triples via the ``cosine_topk`` Pallas
    kernel (DESIGN.md §6) instead of the jnp provider sweep.

    The kernel keeps a running top-k on-chip, so the (rows x |V|) score
    matrix never round-trips to HBM; ``k`` doubles until no row's k-th
    score clears alpha (then the top-k provably contains every >= alpha
    entry).  Per-entry math matches the provider path bit for bit: the
    kernel dots the same L2-normalized rows the provider normalizes per
    block (row-wise normalization is subset-invariant), and clip +
    identity-fix are applied to the returned values exactly as
    ``EmbeddingSimilarity`` applies them to score blocks.  Entries are
    re-ordered to the provider sweep's (vocab block, row, token) order so
    downstream admission order — and therefore every bound — is
    identical.
    """
    import jax.numpy as jnp

    from ..kernels import ops as kops
    from ..runtime import instrument

    vocab = sim_provider.vocab_size
    if not len(stacked):
        z = np.zeros(0, np.int64)
        return z, z.astype(np.int32), np.zeros(0, np.float32)
    # cached device-resident normalized table; query rows gathered on
    # device (no full-table round-trip per call)
    from .similarity import normalized_table_for
    table_n = normalized_table_for(sim_provider)
    qe = table_n[jnp.asarray(stacked)]
    k = min(128, vocab)
    while True:
        instrument.record("h2d:stream_kernel_dispatch")
        instrument.record("d2h:stream_materialize")
        vals, idx = kops.cosine_topk(qe, table_n, k=k)
        vals = np.asarray(vals)
        idx = np.asarray(idx)
        if k == vocab or float(vals[:, -1].max()) < alpha:
            break
        k = min(k * 2, vocab)          # a row saturated: deepen the top-k

    # provider-path value semantics: clip to [0, 1], identity pairs 1.0
    vals = np.clip(vals, 0.0, 1.0)
    vals = np.where(idx == stacked[:, None], np.float32(1.0),
                    vals).astype(np.float32)
    rows, cols = np.nonzero(vals >= alpha)
    q_rows = rows.astype(np.int64)
    token = idx[rows, cols].astype(np.int32)
    sim = vals[rows, cols]

    # identity pairs the top-k cutoff may have missed (always >= alpha)
    key = q_rows * vocab + token
    id_key = np.arange(len(stacked), dtype=np.int64) * vocab + stacked
    missing = ~np.isin(id_key, key)
    q_rows = np.concatenate([q_rows, np.nonzero(missing)[0]])
    token = np.concatenate([token, stacked[missing]])
    sim = np.concatenate([sim, np.ones(missing.sum(), np.float32)])

    # the provider sweep emits (block asc, stacked row asc, token asc)
    order = np.lexsort((token, q_rows, token // block_size))
    return q_rows[order], token[order], sim[order]


def build_token_stream_batch(queries, sim_provider, alpha: float,
                             block_size: int = 4096,
                             use_kernel: bool = False) -> "list[TokenStream]":
    """Token streams for B queries from ONE blocked similarity sweep.

    The queries are stacked into a single (sum |Q_b|, |V|-block) similarity
    matmul per vocabulary block — B times fewer provider dispatches and one
    host-side ``>= alpha`` compaction per block instead of B of them.  Rows
    of the stacked result are exactly the rows each per-query call would
    compute, and the per-query finalize (identity pairs, stable sort) is
    shared with :func:`build_token_stream`, so the returned streams are
    bit-identical to the per-query path.

    ``sim_provider`` must expose ``query_vs_vocab_block(q_ids, lo, hi)`` and
    ``vocab_size``.  Identity pairs (q, q) are always included with sim 1.0
    (paper §V: a query element is returned for itself on first probe — this
    initialises bounds with the vanilla overlap and covers out-of-vocabulary
    elements).
    """
    queries = [np.asarray(q, dtype=np.int32) for q in queries]
    if not queries:
        return []
    vocab = sim_provider.vocab_size
    stacked = np.concatenate(queries)
    # row ranges of each query inside the stacked matrix
    bounds = np.zeros(len(queries) + 1, np.int64)
    np.cumsum([len(q) for q in queries], out=bounds[1:])

    # the kernel path computes cosine from the provider's embedding table;
    # any other similarity (e.g. n-gram Jaccard) falls back to the
    # provider sweep — same gate as the fused schedule's
    if use_kernel and getattr(sim_provider, "name", None) == "cosine":
        q_rows, token, sim = _build_stream_entries_kernel(
            stacked, sim_provider, alpha, block_size)
        out = []
        for b, query in enumerate(queries):
            m = (q_rows >= bounds[b]) & (q_rows < bounds[b + 1])
            out.append(_finalize_stream(
                query, (q_rows[m] - bounds[b]).astype(np.int32),
                token[m], sim[m], vocab))
        return out

    qs = [[] for _ in queries]
    ts = [[] for _ in queries]
    ss = [[] for _ in queries]
    for lo in range(0, vocab, block_size):
        hi = min(lo + block_size, vocab)
        block = np.asarray(sim_provider.query_vs_vocab_block(stacked, lo, hi))
        qi, tj = np.nonzero(block >= alpha)          # one compaction, B queries
        if not len(qi):
            continue
        vals = block[qi, tj].astype(np.float32)
        # qi is ascending (row-major nonzero), so each query's rows are one
        # contiguous slice; split at the stacked row bounds
        cuts = np.searchsorted(qi, bounds)
        for b in range(len(queries)):
            s, e = cuts[b], cuts[b + 1]
            if e > s:
                qs[b].append((qi[s:e] - bounds[b]).astype(np.int32))
                ts[b].append((tj[s:e] + lo).astype(np.int32))
                ss[b].append(vals[s:e])

    out = []
    for b, query in enumerate(queries):
        if qs[b]:
            q_pos = np.concatenate(qs[b])
            token = np.concatenate(ts[b])
            sim = np.concatenate(ss[b])
        else:
            q_pos = np.zeros(0, np.int32)
            token = np.zeros(0, np.int32)
            sim = np.zeros(0, np.float32)
        out.append(_finalize_stream(query, q_pos, token, sim, vocab))
    return out


def build_token_stream(query: np.ndarray, sim_provider, alpha: float,
                       block_size: int = 4096) -> TokenStream:
    """Single-query token stream (see :func:`build_token_stream_batch`)."""
    return build_token_stream_batch([query], sim_provider, alpha,
                                    block_size)[0]


def expand_to_events(stream: TokenStream, index: InvertedIndex) -> EventStream:
    """Expand stream tuples through the inverted index to per-set events.

    Fully vectorized: posting ranges become one flat gather index built from
    repeated range starts plus within-range offsets (cumulative-offset
    trick) — no Python loop over stream tokens.
    """
    counts = index.posting_counts()
    reps = counts[stream.token]
    total = int(reps.sum())
    q_pos = np.repeat(stream.q_pos, reps)
    sim = np.repeat(stream.sim, reps)
    if total:
        starts = index.tok_indptr[stream.token]      # (T,) posting-range lo
        ends = np.cumsum(reps)                       # event offset per tuple
        within = np.arange(total, dtype=np.int64) - np.repeat(ends - reps,
                                                              reps)
        gather = np.repeat(starts, reps) + within
        set_id = index.posting_set[gather]
        slot = index.posting_slot[gather]
    else:
        set_id = np.zeros(0, dtype=np.int32)
        slot = np.zeros(0, dtype=np.int64)
    return EventStream(set_id=set_id, q_pos=q_pos, slot=slot, sim=sim,
                       n_tuples=len(stream))


def pad_events(events: EventStream, chunk: int):
    """Pad event arrays to a power-of-two number of ``chunk``-sized chunks
    (set_id = -1 padding).  Pow2 chunk counts bound jit recompilations of the
    refinement scan to O(log stream-length) distinct shapes."""
    e = len(events)
    n_chunks = max(1, -(-e // chunk))
    p = 1
    while p < n_chunks:
        p *= 2
    n_chunks = p
    total = n_chunks * chunk
    pad = total - e

    def _pad(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])

    last_sim = events.sim[-1] if e else np.float32(1.0)
    return (
        _pad(events.set_id, -1).reshape(n_chunks, chunk),
        _pad(events.q_pos, 0).reshape(n_chunks, chunk),
        _pad(events.slot, 0).reshape(n_chunks, chunk),
        _pad(events.sim, last_sim).reshape(n_chunks, chunk),
    )
