"""KoiosSearch — end-to-end top-k semantic overlap search (paper Fig. 2).

Pipeline per (query x partition) tile:
    token stream (blocked sim matmul, one stacked sweep per request batch)
    ->  event expansion (inverted index)  ->  refinement (chunked
    vectorized filters)  ->  post-processing (No-EM + batched verification
    w/ Lemma-8 early termination).

All execution — single query, request batch, partitioned repository — is
one :class:`repro.core.scheduler.ExecutionPlan` driven by the partition
scheduler: ``search`` IS ``search_batch`` with B=1 IS the scheduler with
P=1.  The default ``overlap`` schedule runs every tile concurrently (async
refinement dispatch, one global cross-partition/cross-query verification
queue, bidirectional theta_lb feedback); ``sequential`` replays the
paper's host loop over partitions with the running-max shared bound —
both return bit-identical exact results (asserted in
tests/test_scheduler.py).  On a device mesh the per-round bound exchange
is an all-reduce-max over the (pod, data) axes (``bound_exchange``; see
``repro.runtime.sharding.all_reduce_max`` and DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from .inverted_index import InvertedIndex
from .scheduler import ExecutionPlan, SchedulerStats, run_plan
from .types import SearchParams, SearchResult, SearchStats, SetCollection


@dataclasses.dataclass
class KoiosIndex:
    """Prebuilt indexes for one partition of the repository."""

    coll: SetCollection
    inv: InvertedIndex
    id_offset: int = 0      # global id of the partition's first set

    @staticmethod
    def build(coll: SetCollection, id_offset: int = 0) -> "KoiosIndex":
        return KoiosIndex(coll=coll, inv=InvertedIndex.build(coll),
                          id_offset=id_offset)


def search_partition(index: KoiosIndex, query: np.ndarray, sim_provider,
                     params: SearchParams,
                     theta_lb0: float = 0.0) -> SearchResult:
    """One query against one partition (compatibility wrapper: a 1x1
    plan); ``theta_lb0`` is the shared global bound."""
    return search_partition_batch(index, [query], sim_provider, params,
                                  [theta_lb0])[0]


def search_partition_batch(index: KoiosIndex, queries: Sequence[np.ndarray],
                           sim_provider, params: SearchParams,
                           theta_lb0s: Sequence[float]
                           ) -> "list[SearchResult]":
    """B queries against one partition (compatibility wrapper: a Bx1 plan
    on the sequential drive — with a single partition the schedules
    coincide).  Per-query results are bit-identical to B
    :func:`search_partition` calls."""
    plan = ExecutionPlan([index], queries, pool_coll=index.coll,
                         theta0=theta_lb0s, request_id_bases=[0])
    return [rs[0] for rs in
            run_plan(plan, sim_provider, params, schedule="sequential")]


def partition_ranges(set_sizes: np.ndarray, partitions: int,
                     by: str = "sets") -> np.ndarray:
    """Contiguous partition boundaries over the repository (paper §VI).

    ``by='sets'``: equal set counts (``np.linspace`` — the historical
    default).  ``by='tokens'``: greedy token-count balancer (DESIGN.md §8
    item 5, resolved): walk the prefix token counts and cut at whichever
    set boundary lands nearest each i/P share of the total, so every
    partition's token count is within half the largest set of the ideal
    share.  Balanced *work* per partition is what keeps fused waves
    uniform enough to overlap (LES3 makes the same observation for
    partition-quality -> exact-search cost).  Boundaries are forced
    strictly increasing, so every partition is non-empty whenever
    ``partitions <= num_sets``."""
    n = len(set_sizes)
    if by == "sets":
        return np.linspace(0, n, partitions + 1).astype(int)
    assert by == "tokens", f"unknown partitioning {by!r}"
    cum = np.concatenate([[0], np.cumsum(set_sizes, dtype=np.int64)])
    targets = cum[-1] * np.arange(1, partitions) / partitions
    cuts = np.searchsorted(cum, targets)
    # nearest set boundary to each target (greedy balance, then monotone)
    cuts = np.where(
        np.abs(cum[np.maximum(cuts - 1, 0)] - targets)
        <= np.abs(cum[np.minimum(cuts, n)] - targets),
        np.maximum(cuts - 1, 0), np.minimum(cuts, n))
    bounds = np.concatenate([[0], cuts, [n]]).astype(int)
    # non-empty partitions: the forward pass pushes collided cuts right
    # (clamped at n), the backward pass pulls the clamped tail left — a
    # single huge set can drag every greedy cut to n, and only the pair
    # of passes guarantees strictly increasing bounds for P <= num_sets
    for i in range(1, len(bounds)):
        bounds[i] = min(max(bounds[i], bounds[i - 1] + 1), n)
    for i in range(len(bounds) - 2, 0, -1):
        bounds[i] = min(bounds[i], bounds[i + 1] - 1)
    # partitions > num_sets cannot all be non-empty: the backward pass
    # then pushes below 0 — clamp and re-monotonize so the caller drops
    # the empty ranges, exactly like the by='sets' linspace path
    return np.maximum.accumulate(np.clip(bounds, 0, n))


def build_partition_indexes(coll: SetCollection, partitions: int,
                            by: str = "sets") -> "list[KoiosIndex]":
    """Build the per-partition indexes of a repository split — THE
    partitioning used by every serving entry point (``KoiosSearch`` and
    the request engine share it, so their plans decompose identically —
    a precondition of the engine == one-shot bit-identity)."""
    out = []
    bounds = partition_ranges(coll.set_sizes, partitions, by=by)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi > lo:
            out.append(KoiosIndex.build(coll.slice_sets(int(lo), int(hi)),
                                        id_offset=int(lo)))
    return out


def merge_topk(results: Sequence[SearchResult], k: int) -> SearchResult:
    """Merge per-partition top-k lists (paper: 'merge-sorted')."""
    ids = np.concatenate([r.ids for r in results])
    lb = np.concatenate([r.lb for r in results])
    ub = np.concatenate([r.ub for r in results])
    order = np.argsort(-lb, kind="stable")[:k]
    stats = SearchStats()
    for r in results:
        for f, v in r.stats.as_dict().items():
            setattr(stats, f, getattr(stats, f) + v if f != "theta_lb_final"
                    else max(getattr(stats, f), v))
    return SearchResult(ids=ids[order], lb=lb[order], ub=ub[order],
                        stats=stats)


class KoiosSearch:
    """Public search API over a (possibly partitioned) repository.

    ``schedule`` selects the default drive order of the partition
    scheduler: 'fused' (default — the on-device wave pipeline where it
    can run, resolving to 'overlap' off-TPU unless ``params.fused ==
    'interpret'``), 'overlap', or 'sequential'; all are exact and
    bit-identical.  ``partition_by`` picks the repository split:
    'sets' (equal set counts) or 'tokens' (greedy token-count balance —
    see :func:`partition_ranges`).  ``bound_exchange`` optionally plugs a
    mesh all-reduce-max into the per-round theta_lb exchange (see
    ``repro.runtime.sharding.all_reduce_max``); ``mesh`` additionally
    moves the fused schedule's exchange on-device.  ``scheduler_stats``
    holds the :class:`SchedulerStats` of the most recent call.
    ``stream_cache`` optionally plugs a
    :class:`~repro.core.token_stream.TokenStreamCache` into the one-shot
    path: repeated queries skip the blocked stream sweep (bit-identical
    streams, DESIGN.md §3.2) — the request engine's cache layer,
    available without the engine.
    """

    def __init__(self, coll: SetCollection, sim_provider,
                 params: Optional[SearchParams] = None,
                 partitions: int = 1, schedule: str = "fused",
                 bound_exchange: Optional[Callable] = None,
                 partition_by: str = "sets", mesh=None,
                 stream_cache=None):
        self.params = params or SearchParams()
        self.sim = sim_provider
        self.coll = coll
        self.schedule = schedule
        self.bound_exchange = bound_exchange
        self.mesh = mesh
        self.stream_cache = stream_cache
        self.scheduler_stats: Optional[SchedulerStats] = None
        self.partitions = build_partition_indexes(coll, partitions,
                                                  by=partition_by)

    def search(self, query: np.ndarray, k: Optional[int] = None,
               schedule: Optional[str] = None) -> SearchResult:
        """Single-query search == ``search_batch`` with B=1."""
        return self.search_batch([query], k=k, schedule=schedule)[0]

    def search_batch(self, queries: Sequence[np.ndarray],
                     k: Optional[int] = None,
                     schedule: Optional[str] = None
                     ) -> "list[SearchResult]":
        """Search B queries — one execution plan, every (query x
        partition) tile through the shared pipeline.

        Results are exact and independent of the schedule and of the
        batch composition: ``search_batch(qs)[i]`` is bit-identical to
        ``search(qs[i])`` (same ids, same lb/ub floats — and on the
        default schedule the same per-phase statistics).
        """
        params = self.params if k is None else dataclasses.replace(
            self.params, k=k)
        queries = [np.asarray(q, dtype=np.int32) for q in queries]
        if not queries:
            return []
        streams = None
        if self.stream_cache is not None:
            from .token_stream import build_token_stream_batch_cached
            streams = build_token_stream_batch_cached(
                queries, self.sim, params.alpha, self.stream_cache,
                use_kernel=params.stream_use_kernel)
        plan = ExecutionPlan(self.partitions, queries, pool_coll=self.coll)
        per_query = run_plan(plan, self.sim, params,
                             schedule=schedule or self.schedule,
                             bound_exchange=self.bound_exchange,
                             mesh=self.mesh, streams=streams)
        self.scheduler_stats = plan.stats
        return [merge_topk(rs, params.k) for rs in per_query]
