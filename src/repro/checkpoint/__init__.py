from .checkpoint import AsyncSaver, restore, save
from .manager import CheckpointManager

__all__ = ["save", "restore", "AsyncSaver", "CheckpointManager"]
