"""Sharded collection resource: ONE logical repository, pod-scale placement.

Every serving layer built before this module sharded *queries*; the
collection itself (CSR inverted index triplet, embedding table, set-norm
metadata) lived whole on one device inside each ``KoiosSearch``.  This
module makes the collection a first-class **resource object**:

:class:`ShardedCollection`
    Owns the repository split — contiguous set ranges over a shard axis
    (paper §VI; LES3 makes the same partition-level-index argument for
    exact set search at corpus scale) — and ALL of its device state.
    Built once, shared by every consumer: ``KoiosSearch`` instances, the
    request engine, engine replicas behind the admission router, and
    benchmarks all borrow the same per-shard operand views, so the CSR
    triplet / dense token matrix / normalized embedding table of a shard
    is uploaded exactly once per process, not once per consumer.

:class:`Shard`
    One contiguous set range: the partition-local :class:`SetCollection`,
    its inverted index, the global id offset, and an optional *placement
    device*.  The search/scheduler/wave layers receive Shards wherever
    they historically received ``KoiosIndex``es (``Shard`` IS a
    ``KoiosIndex`` — same host fields, so the host pipeline is oblivious)
    and **borrow** device operands through the accessors below instead of
    owning uploads:

      ``csr_arrays()``    int32 CSR triplet for in-trace event expansion
      ``wave_operands()`` dense (num_sets, c_pad) token matrix + sizes
      ``table_for(sim)``  the provider's row-normalized embedding table,
                          resident on the shard's device

Placement: ``ShardedCollection.build(..., devices=...)`` pins shard *i*'s
arrays to device *i* (``jax.device_put``); each shard's fused wave then
runs where its data lives, and the theta_lb carry hops device-to-device
between waves (the shared-bound exchange of DESIGN.md §5 — the same
``all_reduce_max`` contract, realised as carry chaining when waves are
driven from one host).  ``devices=None`` leaves every array uncommitted
on the default device — the single-device case is the degenerate 1-place
instance of the same code path, not a fork.

Exactness is placement- and shard-count-invariant: shard boundaries only
change which tile a set's events land in, every per-set numeric is
computed from shard-local operands identical to the unsharded slices, and
the shared theta_lb bound is only ever raised (monotone, certified) — so
sharded top-k is bit-identical to the 1-shard reference
(tests/test_sharded_collection.py asserts this across shard counts x
schedules x verifiers).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

import numpy as np

from ..core.inverted_index import InvertedIndex
from ..core.search import KoiosIndex, partition_ranges
from ..core.types import SetCollection, assert_int32, pow2
from . import instrument


@dataclasses.dataclass
class Shard(KoiosIndex):
    """One contiguous set range of the repository + its device residency.

    Host fields are exactly ``KoiosIndex`` (coll, inv, id_offset), so the
    scheduler's tiles and the host pipeline consume Shards unchanged.
    Device state is built lazily on first borrow and cached on the shard
    — the ShardedCollection (not any search object) is its owner, and its
    lifetime is the resource's lifetime.
    """

    sid: int = 0                     # shard index within the collection
    device: Optional[Any] = None     # placement; None = default device

    def _put(self, x):
        """Upload ``x`` honoring the shard's placement."""
        import jax
        import jax.numpy as jnp

        if self.device is None:
            return jnp.asarray(x)
        return jax.device_put(x, self.device)

    # ------------------------------------------------------------ borrows
    def csr_arrays(self):
        """Device-resident int32 CSR triplet (indptr, posting_set,
        posting_slot) for the fused wave's in-trace event expansion
        (DESIGN.md §3.3) — uploaded once per shard lifetime.

        Unplaced shards delegate to ``InvertedIndex.device_arrays`` —
        ONE cache (and one ``h2d:index_upload`` record) shared with any
        direct index consumer; placed shards pin a committed copy."""
        if self.device is None:
            return self.inv.device_arrays()
        cached = self.__dict__.get("_csr")
        if cached is None:
            assert_int32(self.inv.total_postings, "total_postings")
            instrument.record(f"h2d:index_upload[s{self.sid}]")
            pad = np.zeros(1, np.int32)
            cached = tuple(self._put(a) for a in (
                self.inv.tok_indptr.astype(np.int32),
                np.concatenate(
                    [self.inv.posting_set.astype(np.int32), pad - 1]),
                np.concatenate(
                    [self.inv.posting_slot.astype(np.int32), pad])))
            self._csr = cached
        return cached

    def wave_operands(self):
        """Dense (num_sets, pow2(max set size)) token matrix + int32 set
        sizes + the pow2 column pad — the fused wave's verification
        operands, built and uploaded once per shard lifetime.

        On a size-skewed shard one outlier set inflates ``c_pad`` for
        every row — token-balanced sharding (``by='tokens'``) keeps
        shards uniform; at repository-shard scales the dense form is what
        keeps every round's weight gather one slice."""
        cached = self.__dict__.get("_wave_ops")
        if cached is None:
            coll = self.coll
            sizes = coll.set_sizes
            c_pad = pow2(int(sizes.max()) if len(sizes) else 1)
            dense = np.full((coll.num_sets, c_pad), -1, np.int32)
            if coll.total_tokens:
                rows = np.repeat(np.arange(coll.num_sets), sizes)
                cols = np.arange(coll.total_tokens) \
                    - np.repeat(coll.set_indptr[:-1], sizes)
                dense[rows, cols] = coll.set_tokens
            if self.device is not None:
                instrument.record(f"h2d:operand_upload[s{self.sid}]")
            cached = (self._put(dense), self._put(sizes.astype(np.int32)),
                      c_pad)
            self._wave_ops = cached
        return cached

    def table_for(self, sim_provider):
        """The provider's row-L2-normalized embedding table, resident on
        this shard's device.  Unplaced shards share the provider's own
        cached device table (one upload per provider, process-wide);
        placed shards keep one pinned copy per (provider, device)."""
        from ..core.similarity import normalized_table_for

        table = normalized_table_for(sim_provider)
        if self.device is None:
            return table
        cache = self.__dict__.setdefault("_tables", {})
        hit = cache.get(id(sim_provider))
        if hit is None:
            import jax

            instrument.record(f"h2d:table_upload[s{self.sid}]")
            # pin the provider so its id cannot be recycled while cached
            hit = cache[id(sim_provider)] = (
                jax.device_put(table, self.device), sim_provider)
        return hit[0]


class ShardedCollection:
    """The repository as a shared resource: shards + their device state.

    Consumers (``KoiosSearch``, ``RequestEngine``, engine replicas behind
    the :class:`~repro.runtime.engine.AdmissionRouter`) hold a reference
    and borrow operand views; none of them owns uploads.  Building the
    resource is host-only — device arrays materialize on first borrow.
    """

    def __init__(self, coll: SetCollection, shards: Sequence[Shard]):
        self.coll = coll
        self.shards: List[Shard] = list(shards)

    # ---------------------------------------------------------- factories
    @staticmethod
    def build(coll: SetCollection, shards: int = 1, by: str = "sets",
              devices=None) -> "ShardedCollection":
        """Split ``coll`` into ``shards`` contiguous set ranges
        (``by='sets'`` equal counts / ``by='tokens'`` greedy token
        balance — :func:`repro.core.search.partition_ranges`) and wrap
        each in a :class:`Shard`.

        ``devices``: ``None`` keeps every shard on the default device
        (the degenerate single-place case); ``'auto'`` spreads shards
        round-robin over ``jax.devices()``; an explicit device sequence
        pins shard *i* to ``devices[i % len(devices)]``.  Empty ranges
        (``shards > num_sets``) are dropped, so every shard is
        non-empty."""
        if devices == "auto":
            import jax

            devices = jax.devices()
        bounds = partition_ranges(coll.set_sizes, shards, by=by)
        out: List[Shard] = []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            if hi <= lo:
                continue
            sid = len(out)
            dev = devices[sid % len(devices)] if devices else None
            out.append(Shard(
                coll=coll.slice_sets(int(lo), int(hi)),
                inv=None, id_offset=int(lo), sid=sid, device=dev))
        for s in out:
            s.inv = InvertedIndex.build(s.coll)
        return ShardedCollection(coll, out)

    @staticmethod
    def adopt(coll: SetCollection,
              indexes: Sequence[KoiosIndex]) -> "ShardedCollection":
        """Wrap prebuilt partition indexes (or existing Shards) as a
        collection resource — the compatibility entry for callers that
        built ``KoiosIndex``es directly.  Existing Shards keep their
        cached device state (and sid/placement)."""
        shards = [ix if isinstance(ix, Shard)
                  else Shard(coll=ix.coll, inv=ix.inv,
                             id_offset=ix.id_offset, sid=sid)
                  for sid, ix in enumerate(indexes)]
        return ShardedCollection(coll, shards)

    # ----------------------------------------------------------- geometry
    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def placed(self) -> bool:
        """Whether any shard is pinned to an explicit device."""
        return any(s.device is not None for s in self.shards)

    def shard_ranges(self) -> List[tuple]:
        """[(lo, hi)) global set-id range per shard."""
        return [(s.id_offset, s.id_offset + s.coll.num_sets)
                for s in self.shards]

    def device_bytes(self) -> int:
        """Host-side estimate of the per-shard device footprint already
        materialized (CSR triplets + dense operand matrices)."""
        total = 0
        for s in self.shards:
            if s.__dict__.get("_csr") is not None:
                total += (4 * (s.inv.vocab_size + 1)
                          + 2 * 4 * (s.inv.total_postings + 1))
            ops = s.__dict__.get("_wave_ops")
            if ops is not None:
                total += 4 * s.coll.num_sets * (ops[2] + 1)
        return total

    def describe(self) -> dict:
        """Placement/footprint summary (serving observability)."""
        return {
            "num_sets": self.coll.num_sets,
            "shards": [
                {"sid": s.sid, "sets": s.coll.num_sets,
                 "tokens": s.coll.total_tokens,
                 "device": str(s.device) if s.device is not None else None}
                for s in self.shards],
            "device_bytes": self.device_bytes(),
        }
