"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int, total: int, min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * (min_ratio + (1 - min_ratio) * cos)


def wsd(step, *, warmup: int, total: int, decay_frac: float = 0.1):
    """Warmup-stable-decay (linear cooldown tail)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    decay_start = total * (1 - decay_frac)
    cool = jnp.clip((total - step) / jnp.maximum(total - decay_start, 1),
                    0, 1)
    return warm * cool
