"""Synthetic set repositories statistically matched to the paper's Table I.

The paper's corpora (DBLP'18-19 titles+abstracts, Canada/US OpenData
columns, COVID Twitter, WDC WebTables) are not redistributable offline, so
benchmarks run on generated collections that match the published statistics
(#sets, max/avg cardinality, vocabulary size, element-frequency skew) at a
configurable scale factor.  EXPERIMENTS.md reports the deltas.

Embeddings: FastText vectors are emulated with a clustered unit-vector
table — tokens in the same cluster play the role of synonyms/semantically
related tokens (cosine >= alpha), tokens in different clusters are
unrelated.  This gives the alpha-neighbourhood structure the paper's
filters exercise (a token has a handful of >=0.8 neighbours, not thousands).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.types import SetCollection


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_sets: int
    max_size: int
    avg_size: float
    vocab_size: int
    zipf_a: float          # element frequency skew (1.0 = mild, >1 = heavy)


# Table I of the paper (full scale).
PRESETS = {
    "dblp": DatasetSpec("dblp", 4246, 514, 178.7, 25159, 1.05),
    "opendata": DatasetSpec("opendata", 15636, 31901, 86.4, 179830, 1.01),
    "twitter": DatasetSpec("twitter", 27204, 151, 22.6, 72910, 1.1),
    "wdc": DatasetSpec("wdc", 1014369, 10240, 30.6, 328357, 1.3),
}


def _sizes(spec: DatasetSpec, n: int, rng: np.random.Generator) -> np.ndarray:
    """Log-normal sizes matching avg and max (power-law-ish tail, paper §VIII)."""
    mu = np.log(max(spec.avg_size * 0.6, 2.0))
    sigma = 0.9
    sizes = rng.lognormal(mu, sigma, size=n)
    sizes = np.clip(sizes, 2, spec.max_size).astype(np.int64)
    # rescale mean towards avg_size
    scale = spec.avg_size / max(sizes.mean(), 1.0)
    sizes = np.clip((sizes * scale).astype(np.int64), 2, spec.max_size)
    return sizes


def make_collection(num_sets: int, vocab_size: int, avg_size: float,
                    max_size: int, zipf_a: float = 1.1,
                    seed: int = 0) -> SetCollection:
    spec = DatasetSpec("custom", num_sets, max_size, avg_size, vocab_size,
                       zipf_a)
    return _generate(spec, num_sets, vocab_size, seed)


def _generate(spec: DatasetSpec, num_sets: int, vocab_size: int,
              seed: int) -> SetCollection:
    rng = np.random.default_rng(seed)
    sizes = _sizes(spec, num_sets, rng)
    # Zipfian token popularity over a shuffled vocabulary
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-spec.zipf_a)
    probs /= probs.sum()
    perm = rng.permutation(vocab_size)

    indptr = np.zeros(num_sets + 1, dtype=np.int64)
    np.cumsum(sizes, out=indptr[1:])
    tokens = np.empty(indptr[-1], dtype=np.int32)
    # vectorized draw with per-set dedup (draw extra, unique, trim)
    for i in range(num_sets):
        need = sizes[i]
        draw = rng.choice(vocab_size, size=min(vocab_size, int(need * 2) + 8),
                          p=probs, replace=True)
        uniq = np.unique(draw)
        while len(uniq) < need:
            extra = rng.choice(vocab_size, size=need * 2, p=probs)
            uniq = np.unique(np.concatenate([uniq, extra]))
        pick = rng.permutation(uniq)[:need]
        tokens[indptr[i]:indptr[i + 1]] = perm[pick]
    coll = SetCollection(set_indptr=indptr, set_tokens=tokens,
                         vocab_size=vocab_size)
    coll.validate()
    return coll


def dataset_preset(name: str, scale: float = 1.0,
                   seed: int = 0) -> SetCollection:
    """Generate a Table-I-matched collection at ``scale`` of full size."""
    spec = PRESETS[name]
    num_sets = max(32, int(spec.num_sets * scale))
    vocab = max(256, int(spec.vocab_size * scale))
    sub = DatasetSpec(name, num_sets,
                      max(4, int(spec.max_size * min(1.0, scale * 4))),
                      max(4.0, spec.avg_size * min(1.0, scale * 4)),
                      vocab, spec.zipf_a)
    return _generate(sub, num_sets, vocab, seed)


def make_embeddings(vocab_size: int, dim: int = 64, cluster_size: float = 4.0,
                    intra_cos: float = 0.88, seed: int = 0) -> np.ndarray:
    """Clustered unit-vector embedding table (FastText stand-in).

    ``cluster_size`` is the mean number of tokens per semantic cluster;
    ``intra_cos`` is the expected cosine between two tokens of the same
    cluster (E[cos] ~= 1/(1+sigma^2*dim) for center+noise construction, so
    sigma = sqrt((1/intra_cos - 1)/dim)).  Cross-cluster cosine concentrates
    around 0 (random unit centers), giving the sparse alpha-neighbourhood
    structure the paper's filters exercise.
    """
    rng = np.random.default_rng(seed + 1)
    n_clusters = max(1, int(vocab_size / cluster_size))
    centers = rng.normal(size=(n_clusters, dim))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    assign = rng.integers(0, n_clusters, size=vocab_size)
    sigma = float(np.sqrt(max(1.0 / intra_cos - 1.0, 1e-6) / dim))
    emb = centers[assign] + rng.normal(scale=sigma, size=(vocab_size, dim))
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    return emb.astype(np.float32)


def sample_queries(coll: SetCollection, n_queries: int,
                   card_range: tuple | None = None,
                   seed: int = 0) -> list:
    """Sample query sets from the collection (paper's benchmark protocol:
    uniform sampling, optionally within a cardinality interval)."""
    rng = np.random.default_rng(seed + 2)
    sizes = coll.set_sizes
    if card_range is not None:
        lo, hi = card_range
        pool = np.nonzero((sizes >= lo) & (sizes < hi))[0]
    else:
        pool = np.arange(coll.num_sets)
    if len(pool) == 0:
        return []
    picks = rng.choice(pool, size=min(n_queries, len(pool)), replace=False)
    return [coll.get_set(int(i)).copy() for i in picks]
