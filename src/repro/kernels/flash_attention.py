"""Pallas TPU kernel: causal flash attention (forward / serving path).

§Perf residual of EXPERIMENTS.md cell 1: the unfused softmax(QK^T)V chain
materializes the (S, S) score matrix in HBM between the two matmuls — the
dominant memory term of every dense prefill/train cell.  Flash attention
tiles the computation so scores live only in VMEM: for each query tile the
kernel sweeps KV tiles with a running (max, sum, accumulator) online
softmax; HBM traffic drops from O(S^2) to O(S·d).

Grid: (batch*heads, q_tiles, kv_tiles) with the kv sweep innermost; the
running state lives in VMEM scratch across the sweep (same revisiting
pattern as ssd_scan.py).  Causality: kv tiles entirely above the diagonal
are masked to -inf (they still occupy grid steps — simple and correct;
the production upgrade is a skip via grid pruning).

Serving path only (fwd); training uses the XLA path where remat policy
controls the backward recompute (EXPERIMENTS.md §Perf cell 1 iteration 4).

VMEM per step: 2*bq*d (q, acc) + 2*bk*d (k, v) + bq*bk (scores) + 2*bq
— bq=bk=256, d=128, f32: ~0.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30  # python scalar: jnp constants may not be closure-captured


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, scale: float, causal: bool, s_real: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    v = v_ref[0]                                   # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = cols < s_real          # padded KV columns must not contribute
    if causal:
        valid &= rows >= cols
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...]                            # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attention(q, k, v, *, bq: int = 256, bk: int = 256,
                    causal: bool = True, interpret: bool = False):
    """q, k, v: (B, H, S, d) (same S; GQA expansion upstream).
    Returns (B, H, S, d).  S must divide by the tile sizes (wrapper pads)."""
    B, H, S, d = q.shape
    scale = d ** -0.5
    pad_q = (-S) % bq
    pad_k = (-S) % bk
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq, Sk = S + pad_q, S + pad_k
    qf = q.reshape(B * H, Sq, d)
    kf = k.reshape(B * H, Sk, d)
    vf = v.reshape(B * H, Sk, d)
    grid = (B * H, Sq // bq, Sk // bk)
    kernel = functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                               causal=causal, s_real=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom
            pltpu.VMEM((bq, d), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, d)[:, :, :S]
