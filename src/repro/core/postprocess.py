"""KOIOS post-processing phase (paper Alg. 2) — batched verification.

Survivors of the refinement carry bounds [lb, ub].  We repeatedly:

  1. theta_lb  = k-th largest lb (exact SO counts as lb);
  2. UB-filter: drop sets with ub <= theta_lb (cannot affect the top-k);
  3. No-EM (Lemma 7): sets with lb >= theta_ub (k-th largest ub) are in the
     answer *without* computing a matching;
  4. batch-verify the highest-ub remaining sets:  the whole batch runs
     simultaneously (vmap'd auction — the paper's thread pool becomes batch
     parallelism) with Lemma-8 dual-bound early termination at theta_lb;
     ambiguous auction brackets are re-verified exactly (Hungarian), so the
     search result is exact;
  5. stop when no unverified live set has ub > theta_lb; the answer is the
     top-k by lb.

Verification recomputes the (|Q| x |C|) similarity block on the fly (MXU)
instead of caching refinement similarities — see DESIGN.md §9 item 7.

Multi-query serving (the batched pipeline): the loop above is factored into
a :class:`PostprocessState` state machine that *requests* verification
batches instead of running them inline.  :func:`run_postprocess_batch`
advances B queries' states in lock step and routes every round's pending
requests through one shared :class:`VerifierPool`, which pads-and-vmaps
across queries as well as candidates — fewer, fuller ``auction_batch`` /
``hungarian_batch`` calls with fewer distinct jit shapes.  Requests are
grouped by padded (|Q|, |C|) shape so each row sees exactly the trace it
would in a single-query call: ``search_batch`` results are bit-identical
to per-query ``search``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from .matching.auction import auction_batch, make_eps_schedule
from .matching.hungarian import hungarian_batch
from .types import (SearchParams, SearchResult, SearchStats, SetCollection,
                    pad_ids_pow2, pow2)
from ..runtime import instrument


def _pad_pow2(n: int, lo: int = 8) -> int:
    """Solver-batch bucket rounding (shared pow2 with an 8 floor)."""
    return pow2(n, lo)


def _kth(x: np.ndarray, mask: np.ndarray, kk: int) -> float:
    vals = x[mask]
    if len(vals) < kk:
        return 0.0
    return float(np.partition(vals, -kk)[-kk])


@dataclasses.dataclass
class VerifyRequest:
    """One query's pending verification batch."""

    query: np.ndarray      # (nq,) int32 query token ids
    ids: np.ndarray        # (n,) candidate set ids (partition-local)
    theta_lb: float        # Lemma-8 pruning threshold (-inf to disable)


@dataclasses.dataclass
class VerifyOutcome:
    """Per-request result brackets + matching-count accounting."""

    lb: np.ndarray         # (n,) primal score / exact SO
    ub: np.ndarray         # (n,) dual bound   / exact SO
    early: np.ndarray      # (n,) bool — certified < theta_lb (Lemma 8)
    n_full: int = 0        # full exact matchings computed
    n_early: int = 0       # matchings aborted by the dual bound


class VerifierPool:
    """Shared batched exact-SO verification across any number of queries.

    Every call packs all requests' (query, candidate-set) pairs into padded
    weight tensors and runs one solver call per distinct padded shape —
    the multi-query generalisation of the paper's verification thread pool.
    Shape grouping (pow2-padded |Q| and |C|) keeps the jit cache small AND
    guarantees each row reproduces its single-request numerics exactly.
    """

    def __init__(self, coll: SetCollection, sim_provider,
                 params: SearchParams):
        self.coll = coll
        self.sim = sim_provider
        self.params = params
        self.eps_schedule = make_eps_schedule(params.auction_eps)
        # Collection-level candidate pad: every solver row is padded to
        # the pow2 cover of the LARGEST set in the pool's collection —
        # a composition-independent constant, so (a) an entry's padded
        # shape never depends on which other requests share its round
        # (the auction is NOT bitwise padding-invariant, so a
        # composition-dependent c_pad would break search ==
        # search_batch), and (b) rounds collapse to one solver dispatch
        # per nq bucket instead of one per observed candidate-width
        # bucket — the dominant host<->device round-trip count of the
        # fused schedule's continuation (DESIGN.md §3.3).  The fused
        # wave pays the same cover for its dense operands
        # (``wave._partition_operands``).
        self._c_pad = _pad_pow2(
            int(coll.set_sizes.max()) if coll.num_sets else 1)

    # ---------------------------------------------------------- weights
    # Cap on the candidate tokens one fused pairwise call may cover: the
    # fused matrix computes all requests' rows against all requests'
    # columns, so its waste grows with the number of requests fused —
    # chunking bounds that while typical serving batches still fuse into
    # one dispatch.
    _FUSE_TOKEN_CAP = 16384

    def weights_for_requests(self, requests: Sequence[VerifyRequest]
                             ) -> List[List[np.ndarray]]:
        """Alpha-thresholded (|Q_r|, |C_i|) weight blocks per request,
        fusing as many requests as the token cap allows per ``pairwise``
        dispatch (typically all of them)."""
        all_toks = [[self.coll.get_set(int(i)) for i in r.ids]
                    for r in requests]
        sizes = [sum(len(t) for t in ts) for ts in all_toks]
        out: List[List[np.ndarray]] = []
        lo = 0
        while lo < len(requests):
            hi, tot = lo + 1, sizes[lo]
            while hi < len(requests) and tot + sizes[hi] <= self._FUSE_TOKEN_CAP:
                tot += sizes[hi]
                hi += 1
            out.extend(self._fused_weights(requests[lo:hi],
                                           all_toks[lo:hi]))
            lo = hi
        return out

    def _fused_weights(self, requests: Sequence[VerifyRequest], toks
                       ) -> List[List[np.ndarray]]:
        """One ``pairwise`` dispatch for a run of requests.

        All queries' elements stack into the row axis and all candidate
        sets' tokens into the column axis; each request then slices its own
        (rows, per-set columns) blocks.  Every element is the same
        independent d-dim dot product as a per-set call, so the blocks are
        bit-identical to per-request (and per-set) weight computation.
        """
        assert all(ts for ts in toks), "empty verification request"
        q_cuts = np.zeros(len(requests) + 1, np.int64)
        np.cumsum([len(r.query) for r in requests], out=q_cuts[1:])
        c_cuts = np.zeros(len(requests) + 1, np.int64)
        np.cumsum([sum(len(t) for t in ts) for ts in toks], out=c_cuts[1:])
        q_cat = np.concatenate([np.asarray(r.query, np.int32)
                                for r in requests])
        c_cat = np.concatenate([t for ts in toks for t in ts])
        # pow2 row/col buckets: the fused pairwise shape is otherwise a
        # function of the round's request mix, and steady-state serving
        # (arbitrary cohort coalitions) would compile a fresh program per
        # composition.  Rows/cols of the similarity are independent
        # (row-wise normalize, per-pair dots), so pad entries change no
        # retained value — the slice drops them before use.
        # coarse floors (32 rows / 256 cols) keep the whole bucket grid
        # small enough to warm at engine startup; the extra pad work is
        # one tiny matmul block
        q_in = pad_ids_pow2(q_cat, lo=32)
        c_in = pad_ids_pow2(c_cat, lo=256)
        instrument.record("h2d:pairwise_dispatch")
        instrument.record("d2h:weights_materialize")
        s = np.asarray(self.sim.pairwise(q_in, c_in))[:len(q_cat),
                                                      :len(c_cat)]
        s = np.where(s >= self.params.alpha, s, 0.0).astype(np.float32)
        out = []
        for ri, ts in enumerate(toks):
            block = s[q_cuts[ri]:q_cuts[ri + 1], c_cuts[ri]:c_cuts[ri + 1]]
            cuts = np.zeros(len(ts) + 1, np.int64)
            np.cumsum([len(t) for t in ts], out=cuts[1:])
            out.append([block[:, cuts[i]:cuts[i + 1]]
                        for i in range(len(ts))])
        return out

    def weights_for(self, query: np.ndarray, ids) -> List[np.ndarray]:
        """Weight blocks of one (query, candidate batch) pair."""
        return self.weights_for_requests(
            [VerifyRequest(np.asarray(query, np.int32), np.asarray(ids),
                           float("-inf"))])[0]

    # ---------------------------------------------------- batch building
    def _grouped(self, entries):
        """Pack entries = [(mats, nq, theta), ...] into padded solver
        batches, one per distinct (nq_pad, c_pad) shape.  Yields
        (w, nqs, ncs, thetas, spans) with spans[i] = row range of entry i.
        Rows are independent under vmap, so batch composition never
        changes a row's result."""
        groups: dict = {}
        for i, (mats, nq, _theta) in enumerate(entries):
            key = (_pad_pow2(nq), self._c_pad)
            groups.setdefault(key, []).append(i)
        for (nq_pad, c_pad), idxs in groups.items():
            rows = sum(len(entries[i][0]) for i in idxs)
            # pow2 row padding above verify_batch: cross-query rounds shrink
            # as queries finish, and an exact-fit B would recompile the
            # solver every round (single-query batches stay <= verify_batch,
            # i.e. exactly the historical shape)
            B = _pad_pow2(rows, self.params.verify_batch)
            w = np.zeros((B, nq_pad, c_pad), np.float32)
            nqs = np.zeros(B, np.int32)
            ncs = np.zeros(B, np.int32)
            thetas = np.full(B, -np.inf, np.float32)
            spans = {}
            r = 0
            for i in idxs:
                mats, nq, theta = entries[i]
                for m in mats:
                    w[r, :m.shape[0], :m.shape[1]] = m
                    nqs[r] = nq
                    ncs[r] = m.shape[1]
                    thetas[r] = theta
                    r += 1
                spans[i] = (r - len(mats), r)
            yield w, nqs, ncs, thetas, spans

    def _exact_grouped(self, entries) -> List[np.ndarray]:
        """Exact SO per entry via shape-grouped ``hungarian_batch``."""
        out: List[Optional[np.ndarray]] = [None] * len(entries)
        for w, nqs, ncs, _thetas, spans in self._grouped(entries):
            instrument.record("h2d:solver_dispatch")
            instrument.record("d2h:solver_materialize")
            so, _ = hungarian_batch(jnp.asarray(w), jnp.asarray(nqs),
                                    jnp.asarray(ncs))
            so = np.asarray(so)
            for i, (lo, hi) in spans.items():
                out[i] = so[lo:hi].copy()
        return out

    # ------------------------------------------------------------- verify
    def verify_requests(self, requests: Sequence[VerifyRequest]
                        ) -> List[VerifyOutcome]:
        """Verify all requests' candidates in (few) fused solver calls.

        Brackets are exact (lb == ub == SO) unless early-terminated, in
        which case ub < theta_lb certifies exclusion (Lemma 8).
        """
        all_mats = self.weights_for_requests(requests)
        entries = [(mats, len(r.query), float(r.theta_lb))
                   for mats, r in zip(all_mats, requests)]

        if self.params.verifier == "hungarian":
            return [VerifyOutcome(lb=so, ub=so.copy(),
                                  early=np.zeros(len(so), bool),
                                  n_full=len(so))
                    for so in self._exact_grouped(entries)]

        outcomes: List[Optional[VerifyOutcome]] = [None] * len(requests)
        for w, nqs, ncs, thetas, spans in self._grouped(entries):
            instrument.record("h2d:solver_dispatch")
            instrument.record("d2h:solver_materialize")
            res = auction_batch(jnp.asarray(w), jnp.asarray(nqs),
                                jnp.asarray(ncs), self.eps_schedule,
                                jnp.asarray(thetas))
            lb_all = np.asarray(res.lb)
            ub_all = np.asarray(res.ub)
            early_all = np.asarray(res.early_stopped)
            for i, (lo, hi) in spans.items():
                out = VerifyOutcome(lb=lb_all[lo:hi].copy(),
                                    ub=ub_all[lo:hi].copy(),
                                    early=early_all[lo:hi].copy())
                out.n_early = int(out.early.sum())
                out.n_full = int((~out.early).sum())
                outcomes[i] = out

        # exact fallback for brackets that straddle theta_lb (cannot decide);
        # hybrid mode also tightens any non-degenerate bracket so downstream
        # ordering is exact
        fallback = []
        for i, (req, out) in enumerate(zip(requests, outcomes)):
            amb = (~out.early) & (out.lb < req.theta_lb) \
                & (out.ub > req.theta_lb)
            if self.params.verifier == "hybrid":
                amb |= (~out.early) & (out.ub - out.lb > 1e-6)
            if amb.any():
                fallback.append((i, amb))
        if fallback:
            sub = [( [entries[i][0][j] for j in amb.nonzero()[0]],
                    entries[i][1], float("-inf")) for i, amb in fallback]
            for (i, amb), so in zip(fallback, self._exact_grouped(sub)):
                out = outcomes[i]
                out.lb[amb] = so
                out.ub[amb] = so
                out.n_full += int(amb.sum())
        return outcomes


class Verifier:
    """Per-query facade over :class:`VerifierPool` (baselines, single-query
    post-processing).  Keeps the historical (lb, ub, early) interface and
    stats counters."""

    def __init__(self, coll: SetCollection, query: np.ndarray, sim_provider,
                 params: SearchParams):
        self.pool = VerifierPool(coll, sim_provider, params)
        self.query = np.asarray(query, dtype=np.int32)
        self.stats_em_early = 0
        self.stats_em_full = 0

    def weight_matrix(self, set_id: int) -> np.ndarray:
        return self.pool.weights_for(self.query, [set_id])[0]

    def verify(self, ids, theta_lb: float):
        out = self.pool.verify_requests(
            [VerifyRequest(self.query, np.asarray(ids), float(theta_lb))])[0]
        self.stats_em_early += out.n_early
        self.stats_em_full += out.n_full
        return out.lb, out.ub, out.early


class PostprocessState:
    """Alg. 2 as a resumable state machine for one query.

    ``next_request()`` advances the filters until a verification batch is
    needed (returning a :class:`VerifyRequest`) or the query is finished
    (returning None); ``apply()`` folds the batch's outcome back in.  The
    request/apply cycle is exactly the inline loop of the single-query
    path, which is what lets ``run_postprocess_batch`` drive B queries in
    lock step with bit-identical per-query results.
    """

    def __init__(self, query: np.ndarray, surv_ids: np.ndarray,
                 surv_lb: np.ndarray, surv_ub: np.ndarray, theta_lb0: float,
                 params: SearchParams, stats: SearchStats,
                 id_base: int = 0):
        self.query = np.asarray(query, dtype=np.int32)
        self.params = params
        self.stats = stats
        self.id_base = int(id_base)   # request-id translation (global pool)
        self.ids = np.asarray(surv_ids)
        self.lb = np.asarray(surv_lb, np.float64).copy()
        self.ub = np.asarray(surv_ub, np.float64).copy()
        self.n = len(self.ids)
        self.live = np.ones(self.n, bool)
        self.verified = np.zeros(self.n, bool)
        self.em_early = 0
        self.em_full = 0
        self.theta_lb = max(theta_lb0, _kth(self.lb, self.live, params.k))
        self._guard = 0
        self._phase = "main"
        self._pending: Optional[np.ndarray] = None
        self._cand: Optional[np.ndarray] = None
        self._order: Optional[np.ndarray] = None

    @classmethod
    def from_wave(cls, query: np.ndarray, surv_ids: np.ndarray,
                  lb: np.ndarray, ub: np.ndarray, live: np.ndarray,
                  verified: np.ndarray, em_early: int, em_full: int,
                  theta_lb: float, params: SearchParams, stats: SearchStats,
                  id_base: int = 0) -> "PostprocessState":
        """Resume from the point a fused wave program left off.

        The wave already ran the first R verification rounds on device
        (DESIGN.md §3): ``live``/``verified`` are its masks over the
        refinement survivors, ``lb``/``ub`` its tightened brackets, and
        ``theta_lb`` the on-device-exchanged bound.  Every one of those is
        a certified bound/mask (the wave only prunes on ``ub < theta`` and
        only marks rows verified with sound brackets), so the host drive
        loop continues exactly as if it had run those rounds itself."""
        st = cls(query, surv_ids, lb, ub, float(theta_lb), params, stats,
                 id_base=id_base)
        st.live = np.asarray(live, bool).copy()
        st.verified = np.asarray(verified, bool).copy()
        st.em_early = int(em_early)
        st.em_full = int(em_full)
        return st

    def next_request(self) -> Optional[VerifyRequest]:
        k = self.params.k
        while True:
            if self._phase == "main":
                self._guard += 1
                assert self._guard < 10 * self.n + 100, \
                    "post-processing failed to converge"
                self.theta_lb = max(self.theta_lb,
                                    _kth(self.lb, self.live, k))
                # UB filter (sets that can no longer reach the top-k;
                # strict < keeps ties, which is always safe)
                drop = self.live & (self.ub < self.theta_lb)
                self.stats.pruned_postprocess += int((drop
                                                      & ~self.verified).sum())
                self.live &= ~drop
                theta_ub = _kth(self.ub, self.live, k)
                no_em = self.live & ~self.verified & (self.lb >= theta_ub)
                need = self.live & ~self.verified \
                    & (self.ub > self.theta_lb) & ~no_em
                if not need.any():
                    self.stats.pruned_no_em += int(no_em.sum())
                    self._phase = "assemble"
                    continue
                # verify the highest-ub pending sets as one batch
                nz = need.nonzero()[0]
                order = np.argsort(-self.ub[nz])
                self._pending = nz[order[:self.params.verify_batch]]
                return VerifyRequest(self.query,
                                     self.ids[self._pending] + self.id_base,
                                     float(self.theta_lb))
            if self._phase == "assemble":
                self._cand = self.live.nonzero()[0]
                order = self._cand[np.argsort(-self.lb[self._cand],
                                              kind="stable")][:k]
                if self.params.exact_scores and len(order):
                    pend = order[~self.verified[order]]
                    if len(pend):
                        self._pending = pend
                        self._phase = "exact"
                        return VerifyRequest(self.query,
                                             self.ids[pend] + self.id_base,
                                             float("-inf"))
                self._order = order
                self._phase = "done"
            if self._phase == "done":
                return None

    def raise_theta(self, theta: float) -> None:
        """Externally raise the pruning bound (cross-tile/cross-partition
        feedback from the scheduler).  Monotone and always sound: theta is
        a certified lower bound on the query's global k-th score, and the
        main loop only ever uses theta_lb to discard sets with ub below
        it.  No effect once the final ordering has been assembled."""
        self.theta_lb = max(self.theta_lb, float(theta))

    def finished(self) -> bool:
        return self._phase == "done"

    def apply(self, out: VerifyOutcome) -> None:
        idx = self._pending
        self._pending = None
        self.em_early += out.n_early
        self.em_full += out.n_full
        if self._phase == "main":
            self.lb[idx] = np.maximum(self.lb[idx], out.lb)
            self.ub[idx] = np.minimum(self.ub[idx], out.ub)
            self.verified[idx] = True
            # early-terminated sets are certified below theta_lb
            self.live[idx[out.early]] = False
        else:  # exact-scores pass over the final top-k
            assert self._phase == "exact"
            self.lb[idx] = out.lb
            self.ub[idx] = out.ub
            self.verified[idx] = True
            self._order = self._cand[np.argsort(-self.lb[self._cand],
                                                kind="stable")
                                     ][:self.params.k]
            self._phase = "done"

    def result(self) -> SearchResult:
        assert self._phase == "done", "postprocess state not drained"
        order = self._order
        self.stats.pruned_em_early += self.em_early
        self.stats.exact_matches += self.em_full
        self.stats.theta_lb_final = float(self.theta_lb)
        return SearchResult(
            ids=self.ids[order].astype(np.int32),
            lb=self.lb[order].astype(np.float32),
            ub=self.ub[order].astype(np.float32),
            stats=self.stats,
        )


def drive_states(pool: VerifierPool, states: Sequence[PostprocessState],
                 round_hook=None) -> None:
    """THE post-processing drive loop: advance any number of state
    machines in lock step over one shared verification queue.  Each round
    gathers every unfinished state's pending batch, verifies them all in
    fused solver calls, applies the outcomes, and (optionally) calls
    ``round_hook(n_active)`` — the scheduler's bound-feedback point —
    before the states emit their next requests.  Single-query
    post-processing, the batched pipeline, and the partition scheduler are
    all this loop with different state lists."""
    reqs = {i: st.next_request() for i, st in enumerate(states)}
    while True:
        active = [i for i, r in reqs.items() if r is not None]
        if not active:
            break
        outs = pool.verify_requests([reqs[i] for i in active])
        for i, out in zip(active, outs):
            states[i].apply(out)
        if round_hook is not None:
            round_hook(len(active))
        for i in active:
            reqs[i] = states[i].next_request()


def run_postprocess(coll: SetCollection, query: np.ndarray, sim_provider,
                    surv_ids: np.ndarray, surv_lb: np.ndarray,
                    surv_ub: np.ndarray, theta_lb0: float,
                    params: SearchParams,
                    stats: SearchStats) -> SearchResult:
    """Single-query post-processing — :func:`drive_states` with one state
    (compatibility wrapper)."""
    state = PostprocessState(query, surv_ids, surv_lb, surv_ub, theta_lb0,
                             params, stats)
    return run_postprocess_batch(coll, sim_provider, [state], params)[0]


def run_postprocess_batch(coll: SetCollection, sim_provider,
                          states: Sequence[PostprocessState],
                          params: SearchParams) -> List[SearchResult]:
    """B queries in lock step over one shared queue — a thin wrapper that
    owns the pool and drains the states (see :func:`drive_states`)."""
    pool = VerifierPool(coll, sim_provider, params)
    drive_states(pool, states)
    return [st.result() for st in states]
