"""Sharding-rule tests: logical->mesh mapping, divisibility guard, and
(1,1)-mesh end-to-end lowering of the production step builders."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config, list_archs
from repro.launch.mesh import make_mesh
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step, train_shardings)
from repro.models import build
from repro.runtime.hlo_analysis import normalize_cost_analysis
from repro.runtime.sharding import (_divisibility_guard, input_pspecs,
                                    param_pspecs)


def _spec_of(tree, *path):
    node = tree
    for p in path:
        node = node[p]
    return node


def test_param_rules_dense():
    cfg = get_smoke_config("tinyllama-1.1b")
    model = build(cfg)
    specs = model.param_specs()
    axes = ("data", "model")
    ps = param_pspecs(specs, axes, {"data": 2, "model": 2})
    assert _spec_of(ps, "embed", "table") == P("model", "data")
    # stacked layer leaves get a leading None
    assert _spec_of(ps, "dense_layers", "attn", "wq", "w") == \
        P(None, "data", "model")
    assert _spec_of(ps, "dense_layers", "attn", "wo", "w") == \
        P(None, "model", "data")
    assert _spec_of(ps, "dense_layers", "mlp", "w_down", "w") == \
        P(None, "model", "data")
    assert _spec_of(ps, "final_norm", "scale") == P()


def test_param_rules_moe_and_shared():
    cfg = get_smoke_config("deepseek-v3-671b")
    model = build(cfg)
    specs = model.param_specs()
    ps = param_pspecs(specs, ("data", "model"), {"data": 2, "model": 2})
    moe = ps["moe_layers"]["moe"]
    assert moe["w_gate"] == P(None, "model", "data", None)     # EP
    assert moe["w_down"] == P(None, "model", None, "data")
    # shared experts are dense TP, not expert-sharded
    assert moe["shared"]["w_gate"]["w"] == P(None, "data", "model")
    assert moe["router"]["w"] == P(None, "data", None)


def test_divisibility_guard():
    # dim 7 cannot shard over 2: axis dropped; dim 8 keeps it
    spec = _divisibility_guard(P("model", "data"), (7, 8),
                               {"model": 2, "data": 2})
    assert spec == P(None, "data")
    # multi-axis product
    spec = _divisibility_guard(P(("pod", "data"),), (6,),
                               {"pod": 2, "data": 2})
    assert spec == P(None)


def test_input_rules():
    specs = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "caches": {"k": jax.ShapeDtypeStruct((2, 8, 16, 4, 8),
                                                  jnp.bfloat16)}}
    ps = input_pspecs(specs, ("data", "model"), {"data": 2, "model": 2})
    assert ps["tokens"] == P("data", None)
    assert ps["caches"]["k"] == P(None, "data", None, "model", None)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-v3-671b",
                                  "mamba2-130m", "zamba2-2.7b",
                                  "seamless-m4t-large-v2", "internvl2-1b"])
def test_smoke_train_step_lowers_on_mesh(arch):
    """The production step builder lowers+compiles smoke configs on a
    (1,1) mesh — the same code path as the 256/512-chip dry-run."""
    cfg = get_smoke_config(arch)
    mesh = make_mesh((1, 1), ("data", "model"))
    train_step, model, state_specs, state_ps = make_train_step(cfg, mesh)
    # shrink the batch for CPU: reuse input specs at tiny shapes
    import repro.models.model as mm
    batch = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (2, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["prefix"] = jax.ShapeDtypeStruct(
            (2, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    with mesh:
        compiled = jax.jit(train_step).lower(state_specs, batch).compile()
    ca = normalize_cost_analysis(compiled.cost_analysis())
    assert ca.get("flops", 0) > 0
