"""Pure-SSM decoder LM (mamba2-130m): embed -> N x (norm + mamba2) -> head.

Attention-free: decode state is O(1) in sequence length, which is what
qualifies this family (and the zamba2 hybrid) for the long_500k shape
(DESIGN.md §4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (blocked_xent, dtype_of, embed, embed_init, rmsnorm,
                     rmsnorm_init, softmax_xent, unembed)
from .ssd import mamba2_block, mamba2_decode, mamba2_init


def _block_init(key, cfg, dtype):
    return {"norm": rmsnorm_init(cfg.d_model, dtype),
            "mixer": mamba2_init(key, cfg, dtype)}


def _block_apply(p, cfg, x):
    y, cache = mamba2_block(p["mixer"], cfg, rmsnorm(p["norm"], x))
    return x + y, cache


def _block_decode(p, cfg, x, cache):
    y, new = mamba2_decode(p["mixer"], cfg, rmsnorm(p["norm"], x), cache)
    return x + y, new


class MambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = dtype_of(cfg.dtype)

    def init(self, key):
        cfg = self.cfg
        k0, k1, k2 = jax.random.split(key, 3)
        keys = jax.random.split(k1, cfg.num_layers)
        layers = [_block_init(k, cfg, self.dtype) for k in keys]
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
        params = {"embed": embed_init(k0, cfg.vocab_size, cfg.d_model,
                                      self.dtype),
                  "layers": stacked,
                  "final_norm": rmsnorm_init(cfg.d_model, self.dtype)}
        if not cfg.tie_embeddings:
            out = jax.random.normal(k2, (cfg.d_model, cfg.vocab_size),
                                    jnp.float32) * cfg.d_model ** -0.5
            params["out"] = {"table": out.T.astype(self.dtype)}
        return params

    def param_specs(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    def _logits(self, params, x):
        head = params["embed"] if self.cfg.tie_embeddings or \
            "out" not in params else params["out"]
        return unembed(head, x)

    def _backbone(self, params, x):
        def body(h, layer_p):
            h, cache = _block_apply(layer_p, self.cfg, h)
            return h, cache

        fn = jax.checkpoint(body) if self.cfg.remat != "none" else body
        x, caches = jax.lax.scan(fn, x, params["layers"],
                                 unroll=self.cfg.scan_unroll)
        return rmsnorm(params["final_norm"], x), caches

    def loss(self, params, batch):
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"])
        x, _ = self._backbone(params, x)
        if cfg.xent_block:
            head = params["embed"] if cfg.tie_embeddings or \
                "out" not in params else params["out"]
            return blocked_xent(x[:, :-1], head["table"],
                                batch["labels"][:, 1:], cfg.xent_block)
        logits = self._logits(params, x)
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:])

    # ------------------------------------------------------------- serving
    def cache_specs(self, batch: int, max_seq: int):
        cfg = self.cfg
        s = cfg.ssm
        inner = s.expand * cfg.d_model
        H = inner // s.head_dim
        gs = s.ngroups * s.state_dim
        L = cfg.num_layers
        K = s.conv_width
        return {
            "ssm": jax.ShapeDtypeStruct(
                (L, batch, H, s.head_dim, s.state_dim), jnp.float32),
            "cx": jax.ShapeDtypeStruct((L, batch, K - 1, inner), self.dtype),
            "cb": jax.ShapeDtypeStruct((L, batch, K - 1, gs), self.dtype),
            "cc": jax.ShapeDtypeStruct((L, batch, K - 1, gs), self.dtype),
        }

    def init_cache(self, batch: int, max_seq: int = 0):
        return jax.tree_util.tree_map(
            lambda sp: jnp.zeros(sp.shape, sp.dtype),
            self.cache_specs(batch, max_seq))

    def prefill(self, params, batch, max_seq=None):
        x = embed(params["embed"], batch["tokens"])
        x, caches = self._backbone(params, x)
        return self._logits(params, x[:, -1:]), caches

    def decode_step(self, params, caches, token, cache_index):
        x = embed(params["embed"], token)

        def body(h, xs):
            layer_p, cache = xs
            h, new = _block_decode(layer_p, self.cfg, h, cache)
            return h, new

        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches),
                                     unroll=self.cfg.scan_unroll)
        x = rmsnorm(params["final_norm"], x)
        return self._logits(params, x), new_caches
