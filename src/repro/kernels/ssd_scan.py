"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

The SSD decomposition (Dao & Gu 2024): split the sequence into chunks of
length Tc; within a chunk the recurrence is a masked-decay attention-like
matmul (MXU work), across chunks only a tiny (P, S) state is carried.  The
carried state lives in a VMEM scratch that persists across the sequential
chunk sweep of the grid.

Grid: (batch, heads, n_chunks) with chunks minor, so each (b, h) pair sweeps
its chunks in order; the state scratch is re-initialized at chunk 0.  Head h
reads B/C from its group g = h % G via the index map (GQA-style grouping).

Per-step VMEM: Tc*P (x) + 2*Tc*S (B, C) + Tc*Tc (decay mask) + P*S (state)
— Tc=128, P=64, S=128 f32 => ~180 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xh_ref, dth_ref, ah_ref, bg_ref, cg_ref, dh_ref, y_ref,
            state_ref):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = xh_ref[0, 0]         # (Tc, P)
    dt = dth_ref[0, 0]       # (Tc,)
    A = ah_ref[0, 0]         # scalar (negative)
    Bm = bg_ref[0, 0]        # (Tc, S)
    Cm = cg_ref[0, 0]        # (Tc, S)
    D = dh_ref[0, 0]         # scalar

    a = dt * A                                   # (Tc,) log decay
    cum = jnp.cumsum(a)                          # (Tc,)
    Tc = x.shape[0]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (Tc, Tc), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (Tc, Tc), 1)
    mask = t_idx >= s_idx
    # mask inside the exp: above-diagonal differences are large positive and
    # would overflow (NaN-poisoning any AD through this kernel)
    gate = jnp.exp(jnp.where(mask, cum[:, None] - cum[None, :], -1e30))

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Tc, Tc)
    y_intra = (cb * gate) @ (dt[:, None] * x)              # (Tc, P)

    s0 = state_ref[...]                                    # (P, S)
    y_inter = jnp.exp(cum)[:, None] * (Cm @ s0.T)          # (Tc, P)

    y_ref[0, 0] = y_intra + y_inter + D * x

    # state update: S_end = exp(cum_T) * S0 + sum_s dt_s e^{cum_T-cum_s} x_s (x) B_s
    w = dt * jnp.exp(cum[-1] - cum)                        # (Tc,)
    state_ref[...] = jnp.exp(cum[-1]) * s0 + (w[:, None] * x).T @ Bm


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_chunked(x, dt, A, B, C, D, chunk: int = 64, interpret: bool = False):
    """Batched SSD forward.  Shapes as in ``ref.ssd_ref`` plus batch dim:

      x (Bt, L, H, P), dt (Bt, L, H), A (H,), B (Bt, L, G, S),
      C (Bt, L, G, S), D (H,)  ->  y (Bt, L, H, P).

    L must be a multiple of ``chunk`` (wrapper in ops.py pads).
    """
    Bt, L, H, P = x.shape
    G, S = B.shape[2], B.shape[3]
    assert L % chunk == 0
    n_chunks = L // chunk
    # head-major layouts
    xh = jnp.transpose(x, (0, 2, 1, 3)).astype(jnp.float32)     # (Bt,H,L,P)
    dth = jnp.transpose(dt, (0, 2, 1)).astype(jnp.float32)      # (Bt,H,L)
    bg = jnp.transpose(B, (0, 2, 1, 3)).astype(jnp.float32)     # (Bt,G,L,S)
    cg = jnp.transpose(C, (0, 2, 1, 3)).astype(jnp.float32)
    ah = A.astype(jnp.float32)[:, None]                         # (H,1)
    dh = D.astype(jnp.float32)[:, None]

    grid = (Bt, H, n_chunks)
    y = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, chunk, S), lambda b, h, c: (b, h % G, c, 0)),
            pl.BlockSpec((1, 1, chunk, S), lambda b, h, c: (b, h % G, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, H, L, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, S), jnp.float32)],
        interpret=interpret,
    )(xh, dth, ah, bg, cg, dh)
    return jnp.transpose(y, (0, 2, 1, 3))
