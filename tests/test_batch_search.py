"""Batched multi-query pipeline: bit-exact equivalence with per-query
search across verifier modes, batched token-stream equivalence, and the
vectorized event expansion."""
import numpy as np
import pytest

from repro.core import (EmbeddingSimilarity, InvertedIndex, KoiosSearch,
                        SearchParams, build_token_stream,
                        build_token_stream_batch, expand_to_events)
from repro.data import make_collection, make_embeddings, sample_queries


@pytest.mark.parametrize("verifier", ["hungarian", "auction", "hybrid"])
@pytest.mark.parametrize("partitions", [1, 3])
def test_search_batch_bit_identical(small_world, verifier, partitions):
    """search_batch(queries) == [search(q) for q in queries], bitwise:
    same ids, same lb/ub floats, same per-phase statistics."""
    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          verifier=verifier)
    engine = KoiosSearch(coll, sim, params, partitions=partitions)
    queries = sample_queries(coll, 5, seed=5)
    batch = engine.search_batch(queries)
    assert len(batch) == len(queries)
    for q, rb in zip(queries, batch):
        rs = engine.search(q)
        assert np.array_equal(rs.ids, rb.ids)
        assert np.array_equal(rs.lb, rb.lb)          # bit-identical floats
        assert np.array_equal(rs.ub, rb.ub)
        assert rs.stats.as_dict() == rb.stats.as_dict()


def test_search_batch_k_override(small_world):
    coll, sim = small_world
    engine = KoiosSearch(coll, sim, SearchParams(k=5, alpha=0.8))
    q = sample_queries(coll, 1, seed=9)[0]
    (r3,) = engine.search_batch([q], k=3)
    assert len(r3.ids) <= 3
    assert np.array_equal(r3.ids, engine.search(q, k=3).ids)


def test_search_batch_heterogeneous_queries(small_world):
    """Mixed query lengths (different nq paddings) share one batch."""
    coll, sim = small_world
    engine = KoiosSearch(coll, sim,
                         SearchParams(k=5, alpha=0.8, verify_batch=8))
    rng = np.random.default_rng(0)
    queries = [rng.choice(coll.vocab_size, size=n, replace=False)
               .astype(np.int32) for n in (1, 3, 9, 17)]
    for q, rb in zip(queries, engine.search_batch(queries)):
        rs = engine.search(q)
        assert np.array_equal(rs.ids, rb.ids)
        assert np.array_equal(rs.lb, rb.lb)


def test_build_token_stream_batch_matches_single(small_world):
    coll, sim = small_world
    queries = sample_queries(coll, 4, seed=21)
    streams = build_token_stream_batch(queries, sim, alpha=0.8)
    for q, sb in zip(queries, streams):
        ss = build_token_stream(q, sim, alpha=0.8)
        assert np.array_equal(ss.q_pos, sb.q_pos)
        assert np.array_equal(ss.token, sb.token)
        assert np.array_equal(ss.sim, sb.sim)


def test_build_token_stream_batch_empty():
    assert build_token_stream_batch(
        [], EmbeddingSimilarity(np.eye(4, 3)), alpha=0.8) == []


def test_expand_to_events_matches_naive(small_world):
    """The vectorized posting gather equals the per-token loop."""
    coll, sim = small_world
    inv = InvertedIndex.build(coll)
    q = sample_queries(coll, 1, seed=13)[0]
    stream = build_token_stream(q, sim, 0.8)
    ev = expand_to_events(stream, inv)
    # naive per-tuple expansion oracle
    set_id, q_pos, slot, sim_v = [], [], [], []
    for qp, t, s in zip(stream.q_pos, stream.token, stream.sim):
        sets, slots = inv.postings(int(t))
        set_id.extend(sets.tolist())
        slot.extend(slots.tolist())
        q_pos.extend([qp] * len(sets))
        sim_v.extend([s] * len(sets))
    assert np.array_equal(ev.set_id, np.asarray(set_id, np.int32))
    assert np.array_equal(ev.q_pos, np.asarray(q_pos, np.int32))
    assert np.array_equal(ev.slot, np.asarray(slot, np.int64))
    assert np.array_equal(ev.sim, np.asarray(sim_v, np.float32))
    assert ev.n_tuples == len(stream)
