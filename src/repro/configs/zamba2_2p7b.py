"""zamba2-2.7b [hybrid] — Mamba2 + shared attention blocks.

Assigned: 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  [arXiv:2411.15242; hf]

Deviations (DESIGN.md §4): the original applies the shared block on
concat(h, embedding) with per-invocation LoRA; we apply it on the residual
stream with fully shared weights (structure + FLOP shape preserved at the
assigned dimensions)."""
from repro.models import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, ngroups=1,
                  conv_width=4, chunk=128),
    hybrid=HybridConfig(attn_every=6, shared_weights=True),
    subquadratic=True,       # mamba2 backbone: O(1)-state decode
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
        ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, ngroups=1,
                      conv_width=4, chunk=8),
        hybrid=HybridConfig(attn_every=2), subquadratic=True,
        dtype="float32", remat="none")
