"""Serving driver: batched KOIOS search requests over a sharded corpus.

This is the paper's system as a service: the repository is sharded over the
(pod, data) mesh axes (paper §VI scale-out) and every request batch is one
``ExecutionPlan`` — (query x partition) tiles driven by the partition
scheduler with cross-partition pipelined refinement dispatch, one global
verification queue, and bidirectional theta_lb feedback.  With ``--mesh-bounds`` the
per-round bound exchange runs as a real all-reduce-max over the mesh's
data axis (``repro.runtime.sharding.all_reduce_max``); otherwise the host
reference exchange (a plain max over tiles) is used — same numbers,
DESIGN.md §5.  ``--sequential`` serves with the pre-scheduler partition
loop (the A/B baseline; bit-identical results).

Smoke scale:
    PYTHONPATH=src python -m repro.launch.serve --requests 4 --k 5
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from ..core import (EmbeddingSimilarity, KoiosSearch, SearchParams)
from ..data import (EmbeddingTableProvider, dataset_preset, make_embeddings,
                    sample_queries)


class SearchServer:
    """Batched request loop over a partitioned KOIOS engine.

    ``serve_batch`` runs the whole request batch through one execution
    plan: a stacked similarity sweep shared by every partition, async
    refinement dispatch across (query x partition) tiles, and a shared
    cross-query/cross-partition verification queue.  ``batched=False``
    falls back to per-query plans (identical results — the A/B baseline
    of ``benchmarks/response_time.py``)."""

    def __init__(self, coll, sim, params: SearchParams, partitions: int,
                 schedule: str = "overlap", bound_exchange=None, mesh=None):
        self.engine = KoiosSearch(coll, sim, params, partitions=partitions,
                                  schedule=schedule,
                                  bound_exchange=bound_exchange, mesh=mesh)

    def serve_batch(self, queries, batched: bool = True):
        """One batched request: list of query sets -> list of results."""
        queries = [np.asarray(q, np.int32) for q in queries]
        if batched:
            t0 = time.time()
            results = self.engine.search_batch(queries)
            lat = round((time.time() - t0) / max(len(queries), 1), 4)
            lats = [lat] * len(queries)       # amortized per-query latency
        else:
            results, lats = [], []
            for q in queries:
                t0 = time.time()
                results.append(self.engine.search(q))
                lats.append(round(time.time() - t0, 4))
        return [{
            "ids": res.ids.tolist(),
            "scores": res.lb.tolist(),
            "latency_s": lat,
            "stats": res.stats.as_dict(),
        } for res, lat in zip(results, lats)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="opendata")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=0.8)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--per-query", action="store_true",
                    help="serve each query independently (A/B baseline for "
                         "the default fused multi-query path)")
    sched = ap.add_mutually_exclusive_group()
    sched.add_argument("--sequential", action="store_true",
                       help="drive partitions with the sequential "
                            "running-max loop instead of the overlapped "
                            "scheduler (bit-identical results; A/B "
                            "baseline)")
    sched.add_argument("--fused", action="store_true",
                       help="serve with the fused on-device wave schedule "
                            "(DESIGN.md §3) — one device program per "
                            "partition wave; interpret mode off-TPU; "
                            "bit-identical results")
    ap.add_argument("--mesh-bounds", action="store_true",
                    help="run the theta_lb exchange as an all-reduce-max "
                         "over a device mesh (DESIGN.md §5)")
    args = ap.parse_args(argv)

    bound_exchange = None
    mesh = None
    if args.mesh_bounds:
        from ..runtime.sharding import bound_exchange_for
        from .mesh import bound_exchange_mesh
        mesh = bound_exchange_mesh()
        bound_exchange = bound_exchange_for(mesh)

    print(f"[serve] building corpus ({args.dataset} @ {args.scale})")
    coll = dataset_preset(args.dataset, scale=args.scale, seed=0)
    emb = make_embeddings(coll.vocab_size, dim=args.dim, seed=0)
    sim = EmbeddingTableProvider(emb)
    import jax
    fused_mode = "auto" if jax.default_backend() == "tpu" else (
        "interpret" if args.fused else "auto")
    params = SearchParams(k=args.k, alpha=args.alpha, fused=fused_mode)
    schedule = ("sequential" if args.sequential
                else "fused" if args.fused else "overlap")
    server = SearchServer(coll, sim, params, args.partitions,
                          schedule=schedule,
                          bound_exchange=bound_exchange, mesh=mesh)
    print(f"[serve] corpus: {coll.num_sets} sets, vocab {coll.vocab_size}, "
          f"{args.partitions} partitions, schedule={schedule}")

    queries = sample_queries(coll, args.requests, seed=1)
    for lo in range(0, len(queries), args.batch_size):
        batch = queries[lo:lo + args.batch_size]
        results = server.serve_batch(batch, batched=not args.per_query)
        for i, r in enumerate(results):
            print(f"req {lo+i}: top-{args.k} ids={r['ids'][:5]}... "
                  f"scores={[round(s,2) for s in r['scores'][:5]]} "
                  f"lat={r['latency_s']}s "
                  f"verified={r['stats']['exact_matches']}")
        st = server.engine.scheduler_stats
        if st is not None and not args.per_query:
            # per-query mode runs one plan per query; engine stats hold
            # only the last plan, so the batch-level line would mislead
            print(f"  [scheduler] schedule={st.schedule} tiles={st.tiles} "
                  f"waves={st.waves} device_rounds={st.device_rounds} "
                  f"rounds={st.rounds} "
                  f"fused_requests={st.fused_requests} "
                  f"bound_raises={st.bound_raises} "
                  f"(backward={st.backward_raises})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
