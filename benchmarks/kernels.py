"""Kernel microbenchmarks: us/call of the Pallas kernels (interpret mode on
CPU — correctness-path timing; TPU wall-times come from the roofline
analysis) and their jnp oracles."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import (auction_topk2, auction_topk2_ref, cosine_topk,
                           cosine_topk_ref, ssd, ssd_ref)

from .common import csv_line


def _time(fn, *args, reps=5):
    fn(*args)                     # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)
    return (time.time() - t0) / reps * 1e6


def main():
    rng = np.random.default_rng(0)
    rows = []

    qe = rng.normal(size=(16, 64)).astype(np.float32)
    ev = rng.normal(size=(2048, 64)).astype(np.float32)
    qe /= np.linalg.norm(qe, axis=1, keepdims=True)
    ev /= np.linalg.norm(ev, axis=1, keepdims=True)
    rows.append(("cosine_topk_interp",
                 _time(lambda: cosine_topk(qe, ev, k=16, bv=256)),
                 "nq=16 nv=2048 d=64 k=16"))
    rows.append(("cosine_topk_ref",
                 _time(lambda: cosine_topk_ref(jnp.asarray(qe),
                                               jnp.asarray(ev), 16)),
                 "jnp oracle"))

    wm = rng.random((256, 512)).astype(np.float32)
    pr = rng.random(512).astype(np.float32)
    rows.append(("auction_topk2_interp",
                 _time(lambda: auction_topk2(wm, pr, bn=128)),
                 "n=256 m=512"))
    rows.append(("auction_topk2_ref",
                 _time(lambda: auction_topk2_ref(jnp.asarray(wm),
                                                 jnp.asarray(pr))),
                 "jnp oracle"))

    Bt, L, H, P, G, S = 1, 64, 4, 16, 1, 16
    x = rng.normal(size=(Bt, L, H, P)).astype(np.float32)
    dt = np.log1p(np.exp(rng.normal(size=(Bt, L, H)))).astype(np.float32)
    A = (-np.exp(rng.normal(size=H))).astype(np.float32)
    B = (rng.normal(size=(Bt, L, G, S)) / 4).astype(np.float32)
    C = (rng.normal(size=(Bt, L, G, S)) / 4).astype(np.float32)
    D = rng.normal(size=H).astype(np.float32)
    rows.append(("ssd_interp",
                 _time(lambda: ssd(x, dt, A, B, C, D, chunk=16)),
                 f"B={Bt} L={L} H={H} P={P} S={S}"))
    rows.append(("ssd_ref",
                 _time(lambda: ssd_ref(jnp.asarray(x[0]), jnp.asarray(dt[0]),
                                       jnp.asarray(A), jnp.asarray(B[0]),
                                       jnp.asarray(C[0]), jnp.asarray(D))),
                 "sequential oracle"))

    for name, us, derived in rows:
        print(csv_line(name, us, derived))
    return rows


if __name__ == "__main__":
    main()
