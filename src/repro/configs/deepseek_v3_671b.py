"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

Assigned: 61L d_model=7168 128H (GQA kv=128) d_ff=2048 vocab=129280,
MoE 256e top-8.  [arXiv:2412.19437; hf]

Mapping notes:
 * d_ff=2048 in the assignment is the *routed expert* width; the first 3
   layers are dense with the published d_ff=18432 (cfg.d_ff), remaining 58
   are MoE with one shared expert (DeepSeek-V3 table 1).
 * Attention is MLA (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 /
   v 128); decode uses the absorbed MQA-over-latent form (models/mla.py).
 * MTP (multi-token prediction) is a training-objective head; it is off for
   the roofline runs so MODEL_FLOPS matches 6*N_active*D accounting
   (DESIGN.md §4)."""
from repro.models import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", num_layers=61, d_model=7168,
    num_heads=128, num_kv_heads=128, d_ff=18432, vocab_size=129280,
    head_dim=128,
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048, num_shared=1,
                  first_dense_layers=3, router_renorm=True),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_dim=128))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=160, vocab_size=512, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32, num_shared=1,
                      first_dense_layers=1),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_dim=16),
        dtype="float32", remat="none")
