"""Crash-consistent live collection (DESIGN.md §6.5): copy-on-write
epoch commits, epoch pinning for in-flight requests, write-payloads →
install-manifest snapshot atomicity (restore sees old OR new, never a
torn mix), corruption refusal, quarantine→revive→resync ordering, and
the admission guards (bounded queue + query validation)."""
import glob

import numpy as np
import pytest

from repro.checkpoint import CollectionSnapshotter, SnapshotCorruptionError
from repro.checkpoint.checkpoint import restore as load_tree
from repro.checkpoint.checkpoint import save as save_tree
from repro.core import (KoiosSearch, QueryValidationError, SearchParams,
                        validate_query)
from repro.core.similarity import EmbeddingSimilarity
from repro.data import make_embeddings, sample_queries
from repro.runtime import instrument
from repro.runtime.collection import (ShardedCollection,
                                      UpdateValidationError,
                                      _coll_from_sets)
from repro.runtime.engine import (AdmissionRouter, RequestEngine,
                                  RouterPolicy)
from repro.runtime.fault import FaultEvent, FaultPlan


@pytest.fixture(scope="module", autouse=True)
def _drop_module_jit_residue():
    """This module compiles engine/search programs over many bespoke
    collections (per-epoch shard splits, restored snapshots) that no
    other module reuses.  Drop them from jax's process-global executable
    caches on the way out: the accumulated native compiler state has
    been observed to destabilize later XLA CPU compilations in a long
    single-process suite run (segfault in backend_compile), and
    downstream modules recompile their own shapes anyway."""
    yield
    import jax

    jax.clear_caches()


def _params():
    return SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8)


def _fake_clock():
    t = [1000.0]
    return (lambda: t[0],                       # now
            lambda dt: t.__setitem__(0, t[0] + dt),   # advance
            lambda dt: t.__setitem__(0, t[0] + dt))   # sleep


def _bitwise(a, b):
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.lb, b.lb)


# ----------------------------------------------------- copy-on-write commit
def test_cow_commit_shares_unchanged_shards(small_world):
    """A commit touching the first and last shard rebuilds exactly those
    two; the middle shard's index/device state is shared BY REFERENCE
    into the new epoch, and the committed head serves bit-identically to
    a from-scratch build over the same logical contents."""
    coll, sim = small_world
    sc = ShardedCollection.build(coll, 3)
    base_invs = [id(s.inv) for s in sc.shards]

    added = [coll.get_set(5).copy(), coll.get_set(9).copy()]
    u = sc.begin_update()
    u.remove_sets([0])            # first shard rebuilds
    u.add_sets(added)             # last shard rebuilds
    assert u.commit() == 1
    assert sc.epoch == 1
    assert sc._last_commit["shards_shared"] == 1
    assert sc._last_commit["shards_rebuilt"] == 2
    assert id(sc.shards[1].inv) == base_invs[1]      # shared, not copied
    assert id(sc.shards[0].inv) != base_invs[0]
    assert id(sc.shards[2].inv) != base_invs[2]

    # logical contents: every old set except 0 (order kept), adds at end
    expected = [coll.get_set(i) for i in range(1, coll.num_sets)] + added
    assert sc.coll.num_sets == len(expected)
    for i, ts in enumerate(expected):
        assert np.array_equal(np.sort(sc.coll.get_set(i)), np.sort(ts))

    # bit-parity vs a fresh build (different shard split on purpose)
    fresh = ShardedCollection.build(
        _coll_from_sets(expected, coll.vocab_size), 2)
    params = _params()
    queries = sample_queries(coll, 4, seed=81)
    a = KoiosSearch(None, sim, params, collection=sc).search_batch(queries)
    b = KoiosSearch(None, sim, params,
                    collection=fresh).search_batch(queries)
    for x, y in zip(a, b):
        _bitwise(x, y)


def test_update_transaction_guards(small_world):
    """One open transaction at a time; staged data is validated at the
    staging call (empty set, OOV token, duplicate tokens, bad global id);
    abort reopens; a no-op commit keeps the epoch; a closed transaction
    refuses further use."""
    coll, _ = small_world
    sc = ShardedCollection.build(coll, 2)
    u = sc.begin_update()
    with pytest.raises(UpdateValidationError):
        sc.begin_update()                      # single-transaction guard
    with pytest.raises(UpdateValidationError):
        u.add_sets([np.array([], np.int64)])   # empty set
    with pytest.raises(UpdateValidationError):
        u.add_sets([np.array([coll.vocab_size + 1])])     # OOV token
    with pytest.raises(UpdateValidationError):
        u.add_sets([np.array([3, 3])])         # duplicate tokens
    with pytest.raises(UpdateValidationError):
        u.remove_sets([coll.num_sets + 5])     # bad global id
    u.abort()

    u2 = sc.begin_update()
    assert u2.commit() == 0                    # no-op keeps the epoch
    assert sc.epoch == 0
    with pytest.raises(UpdateValidationError):
        u2.add_sets([coll.get_set(0).copy()])  # closed transaction


def test_reader_drain_releases_old_epoch(small_world):
    """An old epoch (and its rebuilt shards' device/index state) stays
    retained while any reader pins it, and is released — with its
    ``collection:epoch_release`` audit events — when the last reader
    drains.  Shards shared into the head are never dropped."""
    coll, _ = small_world
    sc = ShardedCollection.build(coll, 2)
    ep0 = sc.pin()
    u = sc.begin_update()
    u.remove_sets([0])                         # shard 0 rebuilds,
    assert u.commit() == 1                     # shard 1 is shared

    d = sc.describe()
    assert d["retained_epochs"] == [0, 1]      # the reader pins epoch 0
    assert d["pinned_readers"] == {0: 1}

    with instrument.counting() as events:
        sc.release(ep0)
    d = sc.describe()
    assert d["retained_epochs"] == [1]
    assert not d["pinned_readers"]
    # exactly the REBUILT shard's old state is released; the shared
    # shard lives on in the head
    assert events.get("collection:epoch_release[s0]") == 1
    assert "collection:epoch_release[s1]" not in events


# --------------------------------------------- epoch pinning in the engine
def test_inflight_pinned_epoch_then_resync(small_world):
    """The serving contract across a live commit: requests admitted
    before the commit complete bit-identical to the OLD epoch's one-shot
    reference (their plan never migrates); once drained the standalone
    engine resyncs to the head — new admissions see the new sets and the
    stream cache keys by the new epoch (no stale hits)."""
    coll, sim = small_world
    params = _params()
    sc = ShardedCollection.build(coll, 2)
    queries = sample_queries(coll, 6, seed=82)
    ref_old = KoiosSearch(None, sim, params,
                          collection=sc).search_batch(queries)

    eng = RequestEngine(None, sim, params, collection=sc)
    for q in queries:
        eng.submit(q)
    out = eng.step()                           # admit; waves in flight

    victim = int(ref_old[0].ids[0])            # removing rid 0's top-1
    u = sc.begin_update()                      # guarantees a visible diff
    u.remove_sets([victim])
    u.add_sets([coll.get_set(2).copy()])
    assert u.commit() == 1
    assert eng.epoch == 0 and eng.epoch_behind()

    while eng.pending():                       # drain the pinned cohort
        out.extend(eng.step())
    assert sorted(r.rid for r in out) == list(range(len(queries)))
    assert all(r.epoch == 0 for r in out)      # pre-commit admissions
    for r in out:                              # ... serve the OLD epoch
        _bitwise(r.result, ref_old[r.rid])

    eng.step()                                 # drained -> resync
    assert eng.epoch == 1 and not eng.epoch_behind()
    assert eng.stream_cache.stats()["epoch"] == 1
    assert eng.counters.summary()["resyncs"] == 1

    ref_new = KoiosSearch(None, sim, params,
                          collection=sc).search_batch(queries)
    assert not np.array_equal(ref_old[0].ids, ref_new[0].ids)
    base = len(queries)
    for q in queries:
        eng.submit(q)
    out2 = []
    while eng.pending():
        out2.extend(eng.step())
    assert all(r.epoch == 1 for r in out2)     # post-commit admissions
    for r in out2:                             # ... serve the NEW epoch
        _bitwise(r.result, ref_new[r.rid - base])


def test_quarantine_revive_resyncs_before_readmission(small_world):
    """A commit lands while a replica sits in quarantine: the revive
    path MUST resync it to the head epoch before readmission (audited by
    ``router:revive_resync``), and the whole fleet then serves the new
    epoch bit-identically to its one-shot reference."""
    coll, sim = small_world
    params = _params()
    sc = ShardedCollection.build(coll, 2)
    queries = sample_queries(coll, 4, seed=84)

    clock, advance, sleep = _fake_clock()
    plan = FaultPlan([FaultEvent("verify_error", 0, 1)])
    router = AdmissionRouter(None, sim, params, replicas=2, collection=sc,
                             policy=RouterPolicy(revive_after_s=0.1),
                             fault_plan=plan, clock=clock, sleep=sleep)
    resp = router.serve(queries)
    assert any(r.status == "retried" for r in resp)
    assert 0 in router._quarantined            # still cooling down

    victim = int(resp[0].result.ids[0])
    u = sc.begin_update()
    u.remove_sets([victim])
    u.add_sets([coll.get_set(1).copy()])
    assert u.commit() == 1

    advance(0.2)                               # past the cooldown
    with instrument.counting() as events:
        router.step()                          # revive + rollout pass
    assert events.get("router:revive_resync") == 1
    assert all(e.epoch == 1 for e in router.engines)
    assert router.summary()["replica_epochs"] == [1, 1]

    ref_new = KoiosSearch(None, sim, params,
                          collection=sc).search_batch(queries)
    assert not np.array_equal(resp[0].result.ids, ref_new[0].ids)
    again = router.serve(queries)
    assert all(r.status == "ok" for r in again)
    assert all(r.epoch == 1 for r in again)
    for r, a in zip(again, ref_new):           # gids keep counting up —
        _bitwise(r.result, a)                  # compare by position


# ------------------------------------------------------- admission guards
def test_bounded_admission_queue_overload(small_world):
    """Beyond ``max_pending`` the engine refuses admission with an
    explicit ``failed``/overloaded response (counted in EngineCounters)
    instead of growing without bound; admitted requests are unaffected."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 5, seed=85)
    ref = KoiosSearch(coll, sim, params,
                      partitions=2).search_batch(queries)

    eng = RequestEngine(coll, sim, params, partitions=2, max_pending=2)
    rids = [eng.submit(q) for q in queries]
    assert rids == list(range(5))              # a rid is ALWAYS returned
    out = []
    while eng.pending():
        out.extend(eng.step())
    out.extend(eng.step())                     # flush buffered rejects

    failed = sorted((r for r in out if r.status == "failed"),
                    key=lambda r: r.rid)
    assert [r.rid for r in failed] == [2, 3, 4]
    assert all("overloaded" in r.reason for r in failed)
    assert all(r.waves == 0 for r in failed)   # refused BEFORE any work
    ok = sorted((r for r in out if r.status == "ok"), key=lambda r: r.rid)
    assert [r.rid for r in ok] == [0, 1]
    for r in ok:
        _bitwise(r.result, ref[r.rid])
    s = eng.counters.summary()
    assert s["overloaded"] == 3 and s["failed"] == 3


def test_admission_validation(small_world):
    """Admission-time validation: empty / negative / non-integer queries
    and non-finite embedding rows for in-vocab tokens are refused with a
    typed error at ``search_batch`` and a ``failed`` response at
    ``submit`` — never a garbage top-k.  OOV ids stay legal (the
    identity-pair rule gives them sim 1.0 with themselves only)."""
    coll, sim = small_world
    with pytest.raises(QueryValidationError):
        validate_query(np.array([], np.int32), sim)
    with pytest.raises(QueryValidationError):
        validate_query(np.array([-1, 2]), sim)
    with pytest.raises(QueryValidationError):
        validate_query(np.array([0.5, 2.0]), sim)
    q = validate_query(np.array([coll.vocab_size + 5, 1]), sim)
    assert q.dtype == np.int32                 # OOV ids are legal

    emb = make_embeddings(coll.vocab_size, dim=16, cluster_size=4.0,
                          seed=9)
    emb[7] = np.nan                            # poisoned embedding row
    with pytest.raises(QueryValidationError):
        validate_query(np.array([7, 1]), EmbeddingSimilarity(emb))
    # ...but only for tokens the query actually touches
    validate_query(np.array([6, 1]), EmbeddingSimilarity(emb))

    with pytest.raises(QueryValidationError):
        KoiosSearch(coll, sim, _params()).search_batch(
            [np.array([], np.int32)])

    eng = RequestEngine(coll, sim, _params(), partitions=1)
    rid = eng.submit(np.array([], np.int32))
    (r,) = eng.step()
    assert r.rid == rid and r.status == "failed"
    assert "invalid" in r.reason
    assert eng.counters.summary()["invalid"] == 1


# --------------------------------------------------- snapshot consistency
def test_snapshot_save_restore_roundtrip(tmp_path, small_world):
    """Save → restore reproduces the committed head bit-for-bit: same
    epoch, same shard split, same CSR, bit-identical serving."""
    coll, sim = small_world
    sc = ShardedCollection.build(coll, 2)
    u = sc.begin_update()
    u.remove_sets([3])
    u.add_sets([coll.get_set(1).copy()])
    assert u.commit() == 1
    sc.save(str(tmp_path))

    rest = ShardedCollection.restore(str(tmp_path))
    assert rest is not None and rest.epoch == 1
    assert rest.num_shards == sc.num_shards
    assert rest.shard_ranges() == sc.shard_ranges()
    assert np.array_equal(rest.coll.set_indptr, sc.coll.set_indptr)
    assert np.array_equal(rest.coll.set_tokens, sc.coll.set_tokens)

    params = _params()
    queries = sample_queries(coll, 3, seed=83)
    a = KoiosSearch(None, sim, params, collection=sc).search_batch(queries)
    b = KoiosSearch(None, sim, params,
                    collection=rest).search_batch(queries)
    for x, y in zip(a, b):
        _bitwise(x, y)

    # no snapshot -> a clean None, not an exception
    assert ShardedCollection.restore(str(tmp_path / "nowhere")) is None


def test_crash_mid_commit_restores_old_or_new(tmp_path, small_world):
    """The atomicity contract: payloads land first, the manifest rename
    is the commit point.  A crash BETWEEN the two phases restores the
    OLD epoch intact; after the rename, restore sees the NEW epoch —
    never a torn mix of the two."""
    coll, _ = small_world
    sc = ShardedCollection.build(coll, 2)
    snap = CollectionSnapshotter(str(tmp_path))
    snap.save(sc)                              # epoch 0 durable

    u = sc.begin_update()
    u.remove_sets([0])
    u.add_sets([coll.get_set(4).copy()])
    assert u.commit() == 1

    # phase 1 only: the new payloads are on disk, the manifest is not —
    # exactly the state a crash mid-save leaves behind
    manifest = snap._write_payloads(sc.head)
    rest = snap.restore()
    assert rest.epoch == 0                     # old epoch, fully intact
    assert rest.coll.num_sets == coll.num_sets
    assert np.array_equal(rest.coll.set_tokens, coll.set_tokens)

    # phase 2: one atomic rename flips restore to the new epoch
    snap._install_manifest(manifest)
    snap._gc(manifest)
    rest = snap.restore()
    assert rest.epoch == 1
    assert rest.coll.num_sets == sc.coll.num_sets
    assert np.array_equal(rest.coll.set_tokens, sc.coll.set_tokens)


def test_corrupted_payload_refuses_restore(tmp_path, small_world):
    """Every payload is re-hashed against its manifest sha on restore:
    a single flipped token raises SnapshotCorruptionError instead of
    silently serving wrong top-k."""
    coll, _ = small_world
    ShardedCollection.build(coll, 2).save(str(tmp_path))

    victim = sorted(glob.glob(str(tmp_path / "shard_*.msgpack")))[0]
    tree = load_tree(victim)
    tree["set_tokens"] = np.asarray(tree["set_tokens"], np.int32).copy()
    tree["set_tokens"][0] ^= 1                 # one bit of payload rot
    save_tree(victim, tree)

    with pytest.raises(SnapshotCorruptionError, match="hash mismatch"):
        ShardedCollection.restore(str(tmp_path))
