"""Pallas TPU kernels for the paper's compute hot spots (DESIGN.md §7):

  cosine_topk     — blocked cosine similarity + running top-k (token stream)
  auction_topk2   — fused profit top-2 (auction verification round)
  compact_indices — prefix-sum mask compaction (fused wave candidate sets)
  refine_events   — set-segmented greedy admission of a refinement chunk
                    (VMEM-resident carry, lane-packed levels)
  ssd             — Mamba2 SSD chunked scan (ssm/hybrid architectures)
  flash_attention — causal online-softmax attention (serving/prefill path)

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd wrapper
in ``ops.py`` that switches to interpret mode off-TPU.
"""
from .ops import (auction_topk2, auction_topk2_ref, compact_indices,
                  compact_indices_ref, cosine_topk, cosine_topk_ref,
                  flash_attention, flash_attention_ref, refine_events,
                  refine_events_packed_ref, ssd, ssd_ref)

__all__ = ["cosine_topk", "cosine_topk_ref", "auction_topk2",
           "auction_topk2_ref", "compact_indices", "compact_indices_ref",
           "refine_events", "refine_events_packed_ref",
           "ssd", "ssd_ref", "flash_attention", "flash_attention_ref"]
