"""Paper Fig. 8 + §VIII-E: semantic vs vanilla overlap result quality.

Compares the k-th score of top-k semantic search against top-k vanilla
(exact-match) search and the intersection of the returned id sets —
semantic overlap surfaces sets vanilla search cannot find."""
from __future__ import annotations

import numpy as np

from repro.core import SearchParams, search_partition
from repro.data import sample_queries

from .common import index_for, world


def vanilla_topk(coll, query, k):
    """Exact-match overlap |Q ∩ C| top-k (the classic JOSIE-style measure)."""
    q = set(np.asarray(query).tolist())
    scores = np.array([len(q.intersection(coll.get_set(i).tolist()))
                       for i in range(coll.num_sets)], np.int64)
    ids = np.argsort(-scores, kind="stable")[:k]
    return ids, scores[ids]


def run(datasets=("dblp", "opendata"), n_queries=2, k=10, alpha=0.8):
    rows = []
    params = SearchParams(k=k, alpha=alpha)
    for ds in datasets:
        coll, sim = world(ds)
        index = index_for(ds)
        for qi, q in enumerate(sample_queries(coll, n_queries, seed=23)):
            sem = search_partition(index, q, sim, params)
            van_ids, van_scores = vanilla_topk(coll, q, k)
            inter = len(set(sem.ids.tolist()) & set(van_ids.tolist()))
            # vanilla overlap of the semantic winners (Lemma 1 check)
            van_of_sem = [len(set(np.asarray(q).tolist())
                              & set(coll.get_set(int(i)).tolist()))
                          for i in sem.ids]
            rows.append({
                "dataset": ds, "query": qi, "|Q|": len(q),
                "kth_semantic": float(sem.lb[-1]) if len(sem.lb) else 0.0,
                "kth_vanilla": float(van_scores[-1]) if len(van_scores)
                else 0.0,
                "intersection": inter,
                "semantic_gain": float(np.mean(
                    [s - v for s, v in zip(sem.lb, van_of_sem)])),
            })
    return rows


def main():
    print("dataset,query,|Q|,kth_semantic,kth_vanilla,intersection,"
          "semantic_gain")
    for r in run():
        print(f"{r['dataset']},{r['query']},{r['|Q|']},"
              f"{r['kth_semantic']:.2f},{r['kth_vanilla']:.2f},"
              f"{r['intersection']},{r['semantic_gain']:.2f}")


if __name__ == "__main__":
    main()
