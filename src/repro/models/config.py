"""Unified model configuration for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0            # shared (always-on) experts
    first_dense_layers: int = 0    # leading dense layers (deepseek: 3)
    router_renorm: bool = True     # renormalize top-k weights
    # 'ragged' = dropless sorted ragged_dot (exact; default);
    # 'dispatch' = capacity-based dense dispatch einsum — the EP-friendly
    #   layout GSPMD partitions without gathering expert weights (§Perf,
    #   llama4 hillclimb).  Drops tokens past capacity.
    impl: str = "ragged"
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128           # S
    head_dim: int = 64             # P
    expand: int = 2                # inner = expand * d_model
    ngroups: int = 1               # B/C groups (G)
    conv_width: int = 4
    chunk: int = 64                # SSD chunk length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6            # shared attention block cadence
    shared_weights: bool = True    # one set of attn/mlp weights reused


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # enc-dec (audio family): encoder/decoder depths (num_layers == dec)
    enc_layers: int = 0
    # modality frontends are STUBS: input_specs() provides precomputed
    # frame/patch embeddings of this length prepended to the text tokens
    frontend: Optional[str] = None      # 'vision' | 'audio' | None
    frontend_len: int = 0
    dtype: str = "bfloat16"
    # sub-quadratic attention? (long_500k eligibility, DESIGN.md §4)
    subquadratic: bool = False
    remat: str = "full"            # 'full' | 'dots' | 'none' (see lm.py)
    # fully unroll layer scans (dry-run cost probes: XLA cost_analysis does
    # not multiply while-loop trip counts, see launch/dryrun.py)
    scan_unroll: bool = False
    # blocked head-matmul+cross-entropy vocab block (0 = dense logits);
    # §Perf optimization, see models/layers.py::blocked_xent
    xent_block: int = 0
    # sequence-parallel attention: shard the query sequence over the model
    # axis instead of heads (the TP fix when H doesn't divide the mesh,
    # e.g. llama4's 40 heads / internvl's 14 heads over 16; §Perf)
    attn_seq_parallel: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# the four assigned input shapes (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}
