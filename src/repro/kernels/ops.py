"""Public jit'd wrappers for the Pallas kernels.

On non-TPU backends (this container is CPU-only) every kernel runs in
``interpret=True`` mode — the kernel body executes as traced jnp on CPU, so
correctness (tests/test_kernels.py) is validated against the ``ref.py``
oracles on exactly the code that lowers to Mosaic on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .auction_round import auction_topk2 as _auction_topk2
from .cosine_topk import cosine_topk as _cosine_topk
from .flash_attention import flash_attention as _flash_attention
from .refine_events import refine_events as _refine_events
from .refine_verify import compact_indices as _compact_indices
from .ssd_scan import ssd_chunked as _ssd_chunked


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def cosine_topk(qe, ev, k: int, bv: int = 512):
    """Blocked cosine top-k (token-stream generator).  See cosine_topk.py."""
    return _cosine_topk(jnp.asarray(qe), jnp.asarray(ev), k=k, bv=bv,
                        interpret=_interpret())


def compact_indices(mask):
    """Prefix-sum mask compaction (wave candidate sets).  See
    refine_verify.py."""
    return _compact_indices(jnp.asarray(mask), interpret=_interpret())


def refine_events(state, c_set, c_q, c_slot, c_sim):
    """Set-segmented admission of one lane-packed refinement chunk with a
    VMEM-resident carry.  See refine_events.py."""
    return _refine_events(state, jnp.asarray(c_set), jnp.asarray(c_q),
                          jnp.asarray(c_slot), jnp.asarray(c_sim),
                          interpret=_interpret())


def auction_topk2(wm, prices, bn: int = 256):
    """Fused profit top-2 for one auction round.  See auction_round.py."""
    return _auction_topk2(jnp.asarray(wm), jnp.asarray(prices), bn=bn,
                          interpret=_interpret())


def ssd(x, dt, A, B, C, D, chunk: int = 64):
    """Mamba2 SSD chunked scan; pads L to a multiple of ``chunk``."""
    x = jnp.asarray(x)
    L = x.shape[1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(jnp.asarray(dt), ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(jnp.asarray(B), ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(jnp.asarray(C), ((0, 0), (0, pad), (0, 0), (0, 0)))
    y = _ssd_chunked(x, jnp.asarray(dt), jnp.asarray(A), jnp.asarray(B),
                     jnp.asarray(C), jnp.asarray(D), chunk=chunk,
                     interpret=_interpret())
    return y[:, :L]


def flash_attention(q, k, v, bq: int = 256, bk: int = 256,
                    causal: bool = True):
    """Causal flash attention (serving path).  See flash_attention.py."""
    return _flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            bq=bq, bk=bk, causal=causal,
                            interpret=_interpret())


# re-exported oracles (benchmarks compare against these)
cosine_topk_ref = ref.cosine_topk_ref
compact_indices_ref = ref.compact_indices_ref
refine_events_packed_ref = ref.refine_events_packed_ref
auction_topk2_ref = ref.auction_topk2_ref
ssd_ref = ref.ssd_ref
flash_attention_ref = ref.flash_attention_ref
