"""CSR inverted index I_s: vocabulary token -> sets containing it.

The paper stores I_s as an in-memory hash map of posting lists.  The TPU
adaptation is a CSR matrix over the token axis so a whole stream chunk's
postings are fetched with one vectorized gather (DESIGN.md §2).

``posting_set``  : set id of each posting
``posting_slot`` : index of the posting *within the repository's flat token
                   array* — this is the per-(set, element) slot used by the
                   refinement phase to mark candidate-side elements as
                   matched (the t-side occupancy of the greedy matching).
                   int32 whenever the repository fits (``types.slot_dtype``)
                   — half the event bytes of the historical int64 layout.

``device_arrays`` uploads the CSR triplet once per index lifetime (cached
on the instance) for the fused wave's device-resident event expansion
(DESIGN.md §3.3): stream tuples expand to posting-level events *in-trace*,
so waves consume the compact token stream instead of host-expanded event
arrays.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .types import SetCollection, assert_int32, slot_dtype


@dataclasses.dataclass(frozen=True)
class InvertedIndex:
    tok_indptr: np.ndarray    # (vocab+1,) int64
    posting_set: np.ndarray   # (total_postings,) int32
    posting_slot: np.ndarray  # (total_postings,) int32 flat token-array slot
    #                           (int64 only above 2**31 slots)
    vocab_size: int

    @property
    def total_postings(self) -> int:
        return len(self.posting_set)

    def postings(self, token: int):
        lo, hi = self.tok_indptr[token], self.tok_indptr[token + 1]
        return self.posting_set[lo:hi], self.posting_slot[lo:hi]

    def posting_counts(self) -> np.ndarray:
        cached = self.__dict__.get("_counts")
        if cached is None:
            cached = np.diff(self.tok_indptr)
            object.__setattr__(self, "_counts", cached)
        return cached

    @staticmethod
    def build(coll: SetCollection) -> "InvertedIndex":
        """O(total_tokens) counting-sort construction."""
        tokens = coll.set_tokens.astype(np.int64)
        order = np.argsort(tokens, kind="stable")
        sorted_tokens = tokens[order]
        counts = np.bincount(sorted_tokens, minlength=coll.vocab_size)
        tok_indptr = np.zeros(coll.vocab_size + 1, dtype=np.int64)
        np.cumsum(counts, out=tok_indptr[1:])
        # set id of every flat slot
        set_of_slot = np.repeat(
            np.arange(coll.num_sets, dtype=np.int32), coll.set_sizes)
        return InvertedIndex(
            tok_indptr=tok_indptr,
            posting_set=set_of_slot[order],
            posting_slot=order.astype(slot_dtype(coll.total_tokens)),
            vocab_size=coll.vocab_size,
        )

    def device_arrays(self):
        """Device-resident CSR triplet (indptr, posting_set, posting_slot)
        for in-trace event expansion — uploaded ONCE per index lifetime
        and cached on the instance, killing the per-wave host->device
        event transfer (DESIGN.md §3.3).

        ``indptr`` narrows to int32 (posting counts are bounded by
        ``total_postings``, asserted < 2**31); posting arrays pad by one
        sentinel entry so clipped pad-event gathers stay in bounds even
        for an empty index.
        """
        cached = self.__dict__.get("_device_arrays")
        if cached is None:
            import jax.numpy as jnp

            from ..runtime import instrument

            assert_int32(self.total_postings, "total_postings")
            instrument.record("h2d:index_upload")
            pad = np.zeros(1, np.int32)
            cached = (
                jnp.asarray(self.tok_indptr.astype(np.int32)),
                jnp.asarray(np.concatenate(
                    [self.posting_set.astype(np.int32), pad - 1])),
                jnp.asarray(np.concatenate(
                    [self.posting_slot.astype(np.int32), pad])),
            )
            object.__setattr__(self, "_device_arrays", cached)
        return cached

    def memory_bytes(self) -> int:
        return (self.tok_indptr.nbytes + self.posting_set.nbytes
                + self.posting_slot.nbytes)
