"""Rolling checkpoint manager: step-numbered checkpoints + metadata,
restore-latest, retention, preemption safety (restart resumes mid-run)."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

from .checkpoint import AsyncSaver, default_codec, restore, save

# suffix reflects the on-disk codec: .zst when zstd-compressed, .msgpack
# when written raw (zstandard absent); both are discovered and restored
_PAT = re.compile(r"ckpt_(\d+)\.(zst|msgpack)$")
_SUFFIXES = ("zst", "msgpack")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._saver = AsyncSaver() if async_save else None

    def _path(self, step: int) -> str:
        """Path a new checkpoint for ``step`` will be written to."""
        suffix = "zst" if default_codec() == "zstd" else "msgpack"
        return os.path.join(self.dir, f"ckpt_{step:09d}.{suffix}")

    def _step_paths(self, step: int):
        """Existing checkpoint files for ``step`` (any codec)."""
        return [p for suffix in _SUFFIXES
                if os.path.exists(p := os.path.join(
                    self.dir, f"ckpt_{step:09d}.{suffix}"))]

    def _find_path(self, step: int):
        """Checkpoint file to restore for ``step``.

        A directory can hold the same step under both codecs (run moved
        between hosts with/without zstandard); the newest write wins."""
        paths = self._step_paths(step)
        if not paths:
            return None
        return max(paths, key=os.path.getmtime)

    def steps(self):
        out = set()
        for f in os.listdir(self.dir):
            m = _PAT.match(f)
            if m:
                out.add(int(m.group(1)))      # dedupe mixed-codec dirs
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        meta = dict(metadata or {})
        meta["step"] = step
        meta.setdefault("codec", default_codec())
        payload = {"meta": meta, "state": tree}
        if self._saver is not None:
            self._saver.save(self._path(step), payload)
        else:
            save(self._path(step), payload)
        self._gc()

    def restore_latest(self):
        """Returns (step, state, meta) or None."""
        step = self.latest_step()
        if step is None:
            return None
        self.wait()
        payload = restore(self._find_path(step))
        return step, payload["state"], payload["meta"]

    def wait(self):
        if self._saver is not None:
            self._saver.wait()

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            for p in self._step_paths(s):
                try:
                    os.unlink(p)
                except OSError:
                    pass
