"""Partitioned / distributed search (paper §VI scale-out).

Shows the partition scheduler's shared-theta_lb mechanism: every (query x
partition) tile runs concurrently, verification drains through one global
queue, and a bound raised by ANY tile immediately re-prunes the others —
including tiles of earlier partitions, which the sequential running-max
loop can never reach (on a device mesh the exchange is an all-reduce-max
over the (pod, data) axes, DESIGN.md §5).  Compares the overlapped
schedule against the sequential partition loop at 1 and 4 partitions:
identical results, and the scheduler stats show the bound feedback at
work.

    PYTHONPATH=src python examples/distributed_search.py
"""
import numpy as np

from repro.core import (EmbeddingSimilarity, KoiosSearch, SearchParams)
from repro.data import dataset_preset, make_embeddings, sample_queries

coll = dataset_preset("opendata", scale=0.02, seed=0)
emb = make_embeddings(coll.vocab_size, dim=32, seed=0)
sim = EmbeddingSimilarity(emb)
params = SearchParams(k=10, alpha=0.8)
queries = sample_queries(coll, 4, seed=5)

print(f"corpus: {coll.num_sets} sets, vocab {coll.vocab_size}, "
      f"|Q|={[len(q) for q in queries]}")

for parts in (1, 4):
    engine = KoiosSearch(coll, sim, params, partitions=parts)
    seq = engine.search_batch(queries, schedule="sequential")
    ovl = engine.search_batch(queries, schedule="overlap")
    for a, b in zip(seq, ovl):
        assert np.array_equal(a.ids, b.ids) and np.array_equal(a.lb, b.lb)
    st = engine.scheduler_stats          # stats of the overlapped run
    res = ovl[0]
    print(f"\npartitions={parts}: top-3 scores="
          f"{[round(float(s), 2) for s in res.lb[:3]]} "
          f"(bit-identical to the sequential loop)")
    print(f"  per-query: candidates={res.stats.candidates} "
          f"pruned={res.stats.pruned_refinement} "
          f"verified={res.stats.exact_matches}")
    print(f"  scheduler: tiles={st.tiles} rounds={st.rounds} "
          f"fused_requests={st.fused_requests} "
          f"bound_raises={st.bound_raises} "
          f"(backward to earlier partitions: {st.backward_raises})")
    if st.theta_trace:
        t0 = st.theta_trace[0]
        tN = st.theta_trace[-1]
        print(f"  theta_lb (query 0): {t0[0]:.3f} after refinement "
              f"exchange -> {tN[0]:.3f} final (monotone over "
              f"{len(st.theta_trace)} exchange points)")

print("\noverlapped == sequential is asserted bit-for-bit across "
      "partitions x batch x verifier modes in tests/test_scheduler.py; "
      "on a TPU mesh the bound exchange is an all-reduce-max over the "
      "(pod, data) axes (repro.runtime.sharding.all_reduce_max, "
      "DESIGN.md §5).")
