"""KOIOS refinement phase (paper Alg. 1) — chunked & vectorized.

The event stream (descending similarity, posting-level) is consumed in
fixed-size chunks.  Within a chunk, events are admitted to each set's
partial greedy matching sequentially (exactly the paper's admission order);
after each chunk all bounds are refreshed and the UB filter runs as one
masked vector pass (DESIGN.md §2).  Chunk granularity only *delays* pruning
by at most one chunk — every bound is evaluated at a valid stream position,
so the phase is exact for both ub modes' soundness guarantees.

State arrays (per set):
  S, l      — partial greedy matching score / cardinality (iLB, Lemma 5)
  T, d      — sum / count of first-seen sims per distinct query element
              (sound iUB', DESIGN.md §8.5)
  seen      — appeared in the stream (candidate set)
  alive     — not pruned
  qmatched  — (num_sets, ceil(|Q|/32)) uint32 greedy q-side occupancy
  qseen     — same layout; "query element streamed with this set"
  slot_matched — (total_tokens,) greedy t-side occupancy (flat CSR slots)

After the stream is exhausted every unstreamed pair has sim < alpha and
contributes 0 to SO, so the final bounds drop their s_now terms:
sound mode:  UB_final = T;   paper mode: UB_final = S + m*alpha.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .filters import compute_iub, kth_largest, prune_mask
from .inverted_index import InvertedIndex
from .token_stream import EventStream, pack_events_segmented, pad_events
from .types import SearchStats
from ..kernels.ref import refine_events_packed_ref, refine_events_ref
from ..runtime import instrument


@dataclasses.dataclass
class RefinementResult:
    S: np.ndarray          # (num_sets,) greedy partial score (LB)
    ub: np.ndarray         # (num_sets,) final per-set upper bound
    seen: np.ndarray       # (num_sets,) bool
    alive: np.ndarray      # (num_sets,) bool
    theta_lb: float
    stats: SearchStats


def refine_carry_init(num_sets: int, q_words: int, total_slots: int):
    """Zeroed refinement carry — the state threaded through every chunk.

    Shared by the standalone scan below and the fused wave program
    (``repro.core.wave``), which embeds the same (carry, chunk) -> carry
    step inside one device program per partition wave (DESIGN.md §3)."""
    return (
        jnp.zeros((num_sets,), jnp.float32),          # S
        jnp.zeros((num_sets,), jnp.int32),            # l
        jnp.zeros((num_sets,), jnp.float32),          # T
        jnp.zeros((num_sets,), jnp.int32),            # d
        jnp.zeros((num_sets,), bool),                 # seen
        jnp.ones((num_sets,), bool),                  # alive
        jnp.zeros((num_sets, q_words), jnp.uint32),   # qmatched
        jnp.zeros((num_sets, q_words), jnp.uint32),   # qseen
        jnp.zeros((total_slots,), bool),              # slot_matched
        jnp.float32(0.0),                             # theta_lb
    )


def refine_chunk_step(state, chunk, cap, k: int, ub_mode: str,
                      layout: str = "serial"):
    """One chunk of the refinement scan: greedy admission over the
    chunk's events, then one masked filter pass.  Returns
    (carry, n_killed); suitable for ``lax.scan`` directly and for the
    fused wave program's embedded scan.

    ``layout`` selects the admission schedule (identical bits either
    way — asserted across ub_modes x chunk sizes x partitions in
    tests/test_refinement_segmented.py):

    * ``"serial"`` — the paper's per-event loop: one sequential device
      step per event (E scalar scatters per chunk).
    * ``"segmented"`` — the set-segmented parallel scan (DESIGN.md §2):
      admission walks rank *levels* (at most one event per set each) as
      vectorized scatters, sequential only along each set's own short
      segment.  Two chunk forms are accepted: the lane-packed (W, L)
      arrays plus a trailing per-chunk ``s_now`` scalar
      (``token_stream.pack_events_segmented`` — the standalone host
      path), or flat (E,) arrays plus a trailing within-set rank vector
      (the fused wave's in-trace form after device-side event
      expansion, which cannot compact to data-dependent lane counts).
      Cross-set events commute (all mutated state is per-set and each
      flat slot belongs to one set), so only the within-set order the
      levels preserve is load-bearing.
    """
    S, l, T, d, seen, alive, qmatched, qseen, slot_matched, theta_lb = state
    if layout == "segmented":
        c_set, c_q, c_slot, c_sim, tail = chunk
        admit_state = (S, l, T, d, seen, alive, qmatched, qseen,
                       slot_matched)
        if c_set.ndim == 2:              # lane-packed (W, L) + s_now
            (S, l, T, d, seen, qmatched, qseen, slot_matched) = \
                refine_events_packed_ref(admit_state, c_set, c_q, c_slot,
                                         c_sim)
            s_now = tail
        else:                            # flat (E,) + within-set ranks
            (S, l, T, d, seen, qmatched, qseen, slot_matched) = \
                refine_events_ref(admit_state, c_set, c_q, c_slot, c_sim,
                                  tail)
            s_now = c_sim[-1]
        return _chunk_filter_pass(
            (S, l, T, d, seen, alive, qmatched, qseen, slot_matched,
             theta_lb), s_now, cap, k, ub_mode)
    assert layout == "serial", layout
    c_set, c_q, c_slot, c_sim = chunk
    chunk_len = c_set.shape[0]

    def ev_body(e, st):
        (S, l, T, d, seen, qmatched, qseen, slot_matched) = st
        C = c_set[e]
        q = c_q[e]
        slot = c_slot[e]
        s = c_sim[e]
        valid = C >= 0
        Ci = jnp.maximum(C, 0)
        do = valid & alive[Ci]
        qw = q >> 5
        qb = (q & 31).astype(jnp.uint32)
        bit = jnp.uint32(1) << qb

        # --- first-seen bookkeeping (sound iUB') ------------------------
        qs_word = qseen[Ci, qw]
        first = do & ((qs_word & bit) == 0)
        T = T.at[Ci].add(jnp.where(first, s, 0.0))
        d = d.at[Ci].add(first.astype(jnp.int32))
        qseen = qseen.at[Ci, qw].set(
            jnp.where(first, qs_word | bit, qs_word))
        seen = seen.at[Ci].set(seen[Ci] | do)

        # --- greedy admission (iLB, Lemma 5) ----------------------------
        qm_word = qmatched[Ci, qw]
        q_free = (qm_word & bit) == 0
        t_free = ~slot_matched[slot]
        adm = do & q_free & t_free
        S = S.at[Ci].add(jnp.where(adm, s, 0.0))
        l = l.at[Ci].add(adm.astype(jnp.int32))
        qmatched = qmatched.at[Ci, qw].set(
            jnp.where(adm, qm_word | bit, qm_word))
        slot_matched = slot_matched.at[slot].set(
            slot_matched[slot] | adm)
        return (S, l, T, d, seen, qmatched, qseen, slot_matched)

    (S, l, T, d, seen, qmatched, qseen, slot_matched) = jax.lax.fori_loop(
        0, chunk_len, ev_body,
        (S, l, T, d, seen, qmatched, qseen, slot_matched))
    return _chunk_filter_pass(
        (S, l, T, d, seen, alive, qmatched, qseen, slot_matched, theta_lb),
        c_sim[-1], cap, k, ub_mode)


def _chunk_filter_pass(state, s_now, cap, k: int, ub_mode: str):
    """Vectorized per-chunk filter pass (theta refresh + UB filter) —
    shared by both admission layouts; ``s_now`` is the chunk's final
    stream-order sim (a valid stream position in every layout)."""
    S, l, T, d, seen, alive, qmatched, qseen, slot_matched, theta_lb = state
    theta_lb = jnp.maximum(theta_lb, kth_largest(S, k))
    iub = compute_iub(S, l, T, d, cap, s_now, seen, ub_mode)
    killed = prune_mask(iub, theta_lb, seen, alive)
    alive = alive & ~killed
    n_killed = jnp.sum(killed)
    return (S, l, T, d, seen, alive, qmatched, qseen, slot_matched,
            theta_lb), n_killed


def refine_finalize(state, cap, alpha, k: int, ub_mode: str):
    """Stream exhausted: drop the s_now term (see module docstring) and run
    the final filter pass.  Returns (S, ub_final, seen, alive, theta_lb,
    n_killed_final)."""
    S, l, T, d, seen, alive, _, _, _, theta_lb = state
    s_final = alpha if ub_mode == "paper" else jnp.float32(0.0)
    ub_final = compute_iub(S, l, T, d, cap, s_final, seen, ub_mode)
    theta_lb = jnp.maximum(theta_lb, kth_largest(S, k))
    killed = prune_mask(ub_final, theta_lb, seen, alive)
    alive = alive & ~killed
    return S, ub_final, seen, alive, theta_lb, jnp.sum(killed)


@functools.partial(
    jax.jit,
    static_argnames=("k", "num_sets", "q_words", "total_slots", "ub_mode",
                     "layout"))
def _run_refinement(ev_set, ev_q, ev_slot, ev_sim, ev_snow, cap, k: int,
                    num_sets: int, q_words: int, total_slots: int,
                    ub_mode: str, layout: str, alpha):
    """Scan all chunks.  Serial layout: ev_* are (n_chunks, chunk) and
    ``ev_snow`` is a zero-size placeholder.  Segmented layout: ev_* are
    the lane-packed (n_chunks, W, L) arrays and ``ev_snow`` the
    per-chunk final stream-order sim (see
    ``token_stream.pack_events_segmented``)."""
    state0 = refine_carry_init(num_sets, q_words, total_slots)
    if layout == "segmented":
        chunks = (ev_set, ev_q, ev_slot, ev_sim, ev_snow)
    else:
        chunks = (ev_set, ev_q, ev_slot, ev_sim)
    state, killed_per_chunk = jax.lax.scan(
        lambda s, c: refine_chunk_step(s, c, cap, k, ub_mode,
                                       layout=layout),
        state0, chunks)
    S, ub_final, seen, alive, theta_lb, killed_final = refine_finalize(
        state, cap, alpha, k, ub_mode)
    return (S, ub_final, seen, alive, theta_lb,
            jnp.sum(killed_per_chunk) + killed_final)


def _dispatch_refinement(events: EventStream, set_sizes: np.ndarray, nq: int,
                         total_slots: int, k: int, alpha: float,
                         chunk_size: int, ub_mode: str,
                         layout: str = "segmented"):
    """Launch the jit'd refinement scan; returns (device results, n_chunks)
    without forcing the computation (JAX dispatch is async)."""
    padded = pad_events(events, chunk_size)
    n_chunks = padded[0].shape[0]
    if layout == "segmented":
        ev_set, ev_q, ev_slot, ev_sim, ev_snow = \
            pack_events_segmented(*padded)
    else:
        ev_set, ev_q, ev_slot, ev_sim = padded
        ev_snow = np.zeros(0, np.float32)
    cap = jnp.minimum(jnp.asarray(set_sizes, jnp.int32), jnp.int32(nq))
    # pow2 bitmask width: bounds jit variants to O(log |Q|) shapes
    q_words = max(1, -(-nq // 32))
    p = 1
    while p < q_words:
        p *= 2
    q_words = p
    instrument.record("h2d:refine_dispatch")
    out = _run_refinement(
        jnp.asarray(ev_set), jnp.asarray(ev_q), jnp.asarray(ev_slot),
        jnp.asarray(ev_sim), jnp.asarray(ev_snow), cap, k, len(set_sizes),
        q_words, total_slots, ub_mode, layout, jnp.float32(alpha))
    return out, n_chunks


def _materialize_refinement(out, n_chunks: int,
                            events: EventStream) -> RefinementResult:
    instrument.record("d2h:refine_materialize")
    S, ub, seen, alive, theta_lb, n_pruned = out
    stats = SearchStats(
        candidates=int(jnp.sum(seen)),
        pruned_refinement=int(n_pruned),
        stream_tuples=events.n_tuples,
        stream_events=len(events),
        refinement_chunks=n_chunks,
        theta_lb_final=float(theta_lb),
    )
    return RefinementResult(
        S=np.asarray(S), ub=np.asarray(ub), seen=np.asarray(seen),
        alive=np.asarray(alive), theta_lb=float(theta_lb), stats=stats)


def run_refinement_many(event_streams, nqs, set_sizes: np.ndarray,
                        total_slots: int, k: int, alpha: float,
                        chunk_size: int = 256,
                        ub_mode: str = "sound",
                        layout: str = "segmented"
                        ) -> "list[RefinementResult]":
    """THE refinement entry point: any number of (events, |Q|) pairs with
    pipelined dispatch.

    Each element runs the exact single-query scan (same jit, same operands
    — results are bit-identical however the list is sliced), but all scans
    are dispatched before any result is materialized, overlapping XLA
    execution with the host-side padding/dispatch of later elements.  The
    partition scheduler uses :func:`_dispatch_refinement` /
    :func:`_materialize_refinement` directly to interleave dispatch across
    partitions with different ``set_sizes``.
    """
    launched = [_dispatch_refinement(ev, set_sizes, int(nq), total_slots, k,
                                     alpha, chunk_size, ub_mode,
                                     layout=layout)
                for ev, nq in zip(event_streams, nqs)]
    return [_materialize_refinement(out, n_chunks, ev)
            for (out, n_chunks), ev in zip(launched, event_streams)]


def run_refinement(events: EventStream, set_sizes: np.ndarray, nq: int,
                   total_slots: int, k: int, alpha: float,
                   chunk_size: int = 256,
                   ub_mode: str = "sound",
                   layout: str = "segmented") -> RefinementResult:
    """Single-stream refinement (compatibility wrapper)."""
    return run_refinement_many([events], [nq], set_sizes, total_slots, k,
                               alpha, chunk_size, ub_mode, layout=layout)[0]


def run_refinement_batch(event_streams, queries, set_sizes: np.ndarray,
                         total_slots: int, k: int, alpha: float,
                         chunk_size: int = 256,
                         ub_mode: str = "sound") -> "list[RefinementResult]":
    """B-query refinement (compatibility wrapper over
    :func:`run_refinement_many`)."""
    return run_refinement_many(event_streams, [len(q) for q in queries],
                               set_sizes, total_slots, k, alpha, chunk_size,
                               ub_mode)
