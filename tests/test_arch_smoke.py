"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; plus a prefill->decode consistency check."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import build, input_specs, shape_applicable


def _smoke_batch(cfg, rng, seq=16, batch=2):
    b = {}
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_len, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        b["prefix"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_len, cfg.d_model)),
            jnp.float32)
    toks = rng.integers(0, cfg.vocab_size, size=(batch, seq))
    b["tokens"] = jnp.asarray(toks, jnp.int32)
    b["labels"] = jnp.asarray(toks, jnp.int32)
    return b


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(cfg, rng)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), \
        f"{arch}: non-finite grads"
    # parameter/grad trees are congruent
    assert (jax.tree_util.tree_structure(params)
            == jax.tree_util.tree_structure(grads))


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_consistency(arch):
    """decode_step at position S must reproduce prefill logits of a
    (S+1)-token forward (numerical tolerance)."""
    cfg = get_smoke_config(arch)
    model = build(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.key(1))
    S, B = 8, 2
    batch = _smoke_batch(cfg, rng, seq=S + 1, batch=B)

    full_logits, _ = model.prefill(params, batch)

    # vlm caches cover the prefix region too: decode appends after it
    offset = cfg.frontend_len if cfg.family == "vlm" else 0
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :S]
    _, caches = model.prefill(params, short, max_seq=offset + S + 1)
    step_logits, _ = model.decode_step(
        params, caches, batch["tokens"][:, S:S + 1], jnp.int32(offset + S))

    a = np.asarray(full_logits)[:, -1]
    b = np.asarray(step_logits)[:, -1]
    np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_specs(arch):
    """FULL configs are exercised via shapes only (no allocation):
    param_specs + input_specs must construct for every applicable shape."""
    from repro.configs import get_config
    cfg = get_config(arch)
    model = build(cfg)
    specs = model.param_specs()
    n_params = sum(int(np.prod(s.shape))
                   for s in jax.tree_util.tree_leaves(specs))
    assert n_params > 1e8, f"{arch}: implausibly small ({n_params})"
    for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            continue
        sp = input_specs(cfg, shape)
        assert jax.tree_util.tree_leaves(sp)
