"""Greedy bipartite matching (Lemmas 3 & 5 of the paper).

Greedy matching admits edges in descending-weight order subject to one-to-one
constraints.  Its score lower-bounds the optimal matching (and is >= 1/2 of
it).  KOIOS uses it (a) as the LB-filter oracle and (b) incrementally during
refinement (iLB) — the incremental form lives in ``refinement.py``; this
module is the dense oracle used for tests, the paper's LB-initialisation
experiments, and as a reference for the incremental version.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_NEG = -1e30


@functools.partial(jax.jit, static_argnames=())
def greedy_matching(w: jnp.ndarray):
    """Greedy matching on weight matrix ``w`` (nq, nc), weights >= 0.

    Returns (score, assign) where assign[i] is the column matched to row i
    (-1 for unmatched).  Zero-weight edges are never admitted (matching is
    optional, Def. 1).
    """
    nq, nc = w.shape
    n_steps = min(nq, nc)

    def body(_, state):
        wm, score, assign = state
        flat = jnp.argmax(wm)
        i, j = flat // nc, flat % nc
        val = wm[i, j]
        take = val > 0.0
        # mask out row i and column j
        row_mask = jnp.arange(nq) == i
        col_mask = jnp.arange(nc) == j
        kill = row_mask[:, None] | col_mask[None, :]
        wm = jnp.where(take & kill, _NEG, wm)
        score = score + jnp.where(take, val, 0.0)
        assign = jnp.where(take & row_mask, j, assign)
        return wm, score, assign

    init = (w, jnp.float32(0.0), jnp.full((nq,), -1, dtype=jnp.int32))
    _, score, assign = jax.lax.fori_loop(0, n_steps, body, init)
    return score, assign


def greedy_matching_score(w: jnp.ndarray) -> jnp.ndarray:
    return greedy_matching(w)[0]
