"""The paper's own configuration: KOIOS search defaults (§VIII-A3) and the
Table-I dataset presets.  See repro.core.SearchParams / repro.data.PRESETS."""
from repro.core import SearchParams
from repro.data import PRESETS  # noqa: F401  (re-export)

# alpha=0.8, k=10, partitions=10 — the defaults of every paper experiment
SEARCH_DEFAULTS = SearchParams(k=10, alpha=0.8)
PARTITIONS = 10
