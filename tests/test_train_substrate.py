"""Substrate tests: optimizer, schedules, grad utils, checkpointing, data
pipeline determinism, fault-tolerance state machine."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress, decompress,
                         global_norm, warmup_cosine, wsd, zero_residual)
from repro.checkpoint import CheckpointManager, restore, save
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.runtime import (FaultConfig, FleetMonitor, plan_elastic_mesh,
                           resume_plan)


# ---------------------------------------------------------------- optimizer
def _toy_params():
    return {"a": {"w": jnp.ones((4, 4), jnp.bfloat16)},
            "b": jnp.arange(4, dtype=jnp.float32)}


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16"])
def test_adamw_dtypes(state_dtype):
    cfg = AdamWConfig(state_dtype=state_dtype)
    params = _toy_params()
    state = adamw_init(params, cfg)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    rng = jax.random.key(0) if state_dtype == "bfloat16" else None
    new_p, new_s = adamw_update(grads, state, params, cfg, rng=rng)
    # params keep their dtype; moments use the state dtype
    assert new_p["a"]["w"].dtype == jnp.bfloat16
    want = jnp.bfloat16 if state_dtype == "bfloat16" else jnp.float32
    assert new_s["mu"]["a"]["w"]["m"].dtype == want
    assert int(new_s["count"]) == 1


def test_clip_by_global_norm():
    tree = {"x": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 30


def test_schedules_monotone_warmup():
    assert float(warmup_cosine(jnp.asarray(0), warmup=10, total=100)) == 0.0
    mid = float(warmup_cosine(jnp.asarray(10), warmup=10, total=100))
    assert abs(mid - 1.0) < 1e-6
    assert float(wsd(jnp.asarray(100), warmup=10, total=100)) == 0.0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_compression_error_feedback(seed):
    """int8 EF compression: the residual carries exactly the quantization
    error, so compressed-sum + residual == true value."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(32,)) * 3, jnp.float32)}
    res = zero_residual(g)
    q, scales, new_res = compress(g, res)
    deq = decompress(q, scales)
    np.testing.assert_allclose(
        np.asarray(deq["w"]) + np.asarray(new_res["w"]),
        np.asarray(g["w"]), atol=1e-5)
    assert q["w"].dtype == jnp.int8


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"p": {"w": jnp.ones((3, 3), jnp.bfloat16)},
            "step": 7, "name": "x",
            "arr": np.arange(5, dtype=np.int64)}
    path = os.path.join(tmp_path, "ck.zst")
    save(path, tree)
    back = restore(path)
    assert back["step"] == 7 and back["name"] == "x"
    assert back["p"]["w"].dtype.name == "bfloat16"
    np.testing.assert_array_equal(back["arr"], tree["arr"])


def test_checkpoint_manager_rolling(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, {"v": jnp.asarray([s])}, {"mesh": [2, 2]})
    assert mgr.steps() == [2, 3]
    step, state, meta = mgr.restore_latest()
    assert step == 3 and meta["mesh"] == [2, 2]
    assert int(state["v"][0]) == 3


def test_checkpoint_manager_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, {"v": jnp.ones((128, 128))})
    mgr.wait()
    assert mgr.latest_step() == 1


# --------------------------------------------------------------------- data
def test_data_deterministic_and_restart_safe():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    np.testing.assert_array_equal(a.global_batch(5)["tokens"],
                                  b.global_batch(5)["tokens"])


def test_data_resharding_partitions_same_stream():
    """Elastic re-shard: 2-way and 4-way shards tile the same global
    batch."""
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=1)
    ds = SyntheticLM(cfg)
    g = ds.global_batch(9)["tokens"]
    two = np.concatenate([ds.shard_batch(9, s, 2)["tokens"]
                          for s in range(2)])
    four = np.concatenate([ds.shard_batch(9, s, 4)["tokens"]
                           for s in range(4)])
    np.testing.assert_array_equal(g, two)
    np.testing.assert_array_equal(g, four)


# ------------------------------------------------------------------- fault
def test_fleet_failure_detection():
    t = [0.0]
    mon = FleetMonitor(4, FaultConfig(heartbeat_timeout=10.0),
                       clock=lambda: t[0])
    for h in range(4):
        mon.heartbeat(h, 0, 1.0)
    t[0] = 5.0
    for h in range(3):           # host 3 goes silent
        mon.heartbeat(h, 1, 1.0)
    t[0] = 12.0                  # 12-5=7 < timeout for 0-2; 12-0=12 > 10
    assert mon.failed_hosts() == [3]


def test_straggler_detection_patience():
    mon = FleetMonitor(4, FaultConfig(straggler_factor=2.0,
                                      straggler_patience=2))
    for round_ in range(2):
        for h in range(4):
            mon.heartbeat(h, round_, 10.0 if h == 2 else 1.0)
        strag = mon.stragglers()
    assert strag == [2]


def test_elastic_mesh_plan():
    assert plan_elastic_mesh(512, 16) == (32, 16)
    assert plan_elastic_mesh(480, 16) == (16, 16)   # pow2 data axis
    assert plan_elastic_mesh(8, 16) is None


def test_resume_plan_end_to_end():
    t = [0.0]
    mon = FleetMonitor(8, FaultConfig(heartbeat_timeout=5.0),
                       clock=lambda: t[0])
    for h in range(8):
        mon.heartbeat(h, 0, 1.0)
    t[0] = 10.0
    for h in range(6):
        mon.heartbeat(h, 1, 1.0)
    plan = resume_plan(mon, chips_per_host=4, model_axis=4)
    assert sorted(plan["evicted_failed"]) == [6, 7]
    assert plan["mesh"] == (4, 4)       # 24 chips -> data 4 (pow2), model 4
    assert plan["action"] == "continue"
