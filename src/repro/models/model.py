"""Model registry: config -> model object + input specs per assigned shape.

``build(cfg)`` returns an object exposing:
    init(key) / param_specs()
    loss(params, batch)                      (train shapes)
    prefill(params, batch[, max_seq])        (prefill shapes)
    decode_step(params, caches, token, i)    (decode shapes)
    cache_specs(batch, max_seq)

``input_specs(cfg, shape_name)`` returns ShapeDtypeStruct stand-ins for the
step inputs — weak-type-correct, shardable, no device allocation (the
pattern the multi-pod dry-run requires)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import SHAPES, ModelConfig
from .encdec import EncDecLM
from .hybrid import HybridLM
from .lm import DecoderLM
from .ssm_lm import MambaLM


def build(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def shape_kind(shape_name: str) -> str:
    return SHAPES[shape_name][2]


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """DESIGN.md §4 applicability matrix."""
    seq, batch, kind = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k context skipped"
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStructs for the step inputs of (cfg, shape)."""
    seq, batch, kind = SHAPES[shape_name]
    i32 = jnp.int32
    bf = jnp.bfloat16

    def tok(s):
        return jax.ShapeDtypeStruct((batch, s), i32)

    if kind == "train":
        if cfg.family == "audio":
            return {"frames": jax.ShapeDtypeStruct(
                        (batch, cfg.frontend_len, cfg.d_model), bf),
                    "tokens": tok(seq), "labels": tok(seq)}
        if cfg.family == "vlm":
            text = seq - cfg.frontend_len
            return {"prefix": jax.ShapeDtypeStruct(
                        (batch, cfg.frontend_len, cfg.d_model), bf),
                    "tokens": tok(text), "labels": tok(text)}
        return {"tokens": tok(seq), "labels": tok(seq)}

    if kind == "prefill":
        if cfg.family == "audio":
            return {"frames": jax.ShapeDtypeStruct(
                        (batch, cfg.frontend_len, cfg.d_model), bf),
                    "tokens": tok(seq)}
        if cfg.family == "vlm":
            return {"prefix": jax.ShapeDtypeStruct(
                        (batch, cfg.frontend_len, cfg.d_model), bf),
                    "tokens": tok(seq - cfg.frontend_len)}
        return {"tokens": tok(seq)}

    # decode: one new token against a seq-length cache
    model = build(cfg)
    caches = model.cache_specs(batch, seq)
    return {"caches": caches,
            "token": jax.ShapeDtypeStruct((batch, 1), i32),
            "cache_index": jax.ShapeDtypeStruct((), i32)}
