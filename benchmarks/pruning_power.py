"""Paper Table II / IV / V: pruning power of the filters.

Per dataset (and optionally per query-cardinality interval): candidate
sets, iUB-filtered during refinement, No-EM acceptances, EM-early
terminations, and full exact matchings — the percentages the paper's
central claim rests on (<5% of candidates verified for medium/large
queries)."""
from __future__ import annotations

import numpy as np

from repro.core import SearchParams, search_partition
from repro.data import sample_queries

from .common import index_for, world


def run(datasets=("dblp", "opendata", "twitter", "wdc"), n_queries=3,
        k=10, alpha=0.8, by_cardinality=False, ub_mode="sound"):
    rows = []
    params = SearchParams(k=k, alpha=alpha, ub_mode=ub_mode)
    for ds in datasets:
        coll, sim = world(ds)
        index = index_for(ds)
        if by_cardinality:
            sizes = coll.set_sizes
            qs = np.unique(np.quantile(sizes, [0.25, 0.5, 0.75]))
            edges = [2.0] + [q for q in qs if q > 2] + [sizes.max() + 1.0]
            intervals = [(lo, hi) for lo, hi in zip(edges[:-1], edges[1:])
                         if hi > lo]
        else:
            intervals = [None]
        for interval in intervals:
            queries = sample_queries(coll, n_queries, card_range=interval,
                                     seed=7)
            agg = {"candidates": 0, "iub_filtered": 0, "no_em": 0,
                   "em_early": 0, "em_full": 0, "post_ub": 0}
            for q in queries:
                res = search_partition(index, q, sim, params)
                st = res.stats
                agg["candidates"] += st.candidates
                agg["iub_filtered"] += st.pruned_refinement
                agg["no_em"] += st.pruned_no_em
                agg["em_early"] += st.pruned_em_early
                agg["em_full"] += st.exact_matches
                agg["post_ub"] += st.pruned_postprocess
            nq = max(len(queries), 1)
            cand = max(agg["candidates"], 1)
            rows.append({
                "dataset": ds,
                "interval": (f"{int(interval[0])}-{int(interval[1])}"
                             if interval else "all"),
                "queries": len(queries),
                **{key: v / nq for key, v in agg.items()},
                "refine_prune_pct": 100 * agg["iub_filtered"] / cand,
                "verified_pct": 100 * agg["em_full"] / cand,
            })
    return rows


def main():
    print("dataset,interval,candidates,iUB%,No-EM,EM-early,EM,verified%")
    for r in run():
        print(f"{r['dataset']},{r['interval']},{r['candidates']:.0f},"
              f"{r['refine_prune_pct']:.1f},{r['no_em']:.1f},"
              f"{r['em_early']:.1f},{r['em_full']:.1f},"
              f"{r['verified_pct']:.2f}")


if __name__ == "__main__":
    main()
