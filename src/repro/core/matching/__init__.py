from .greedy import greedy_matching_score, greedy_matching
from .hungarian import hungarian_score, hungarian_batch
from .auction import (auction_score_bounds, auction_batch, AuctionResult,
                      make_eps_schedule)

__all__ = [
    "greedy_matching_score",
    "greedy_matching",
    "hungarian_score",
    "hungarian_batch",
    "auction_score_bounds",
    "auction_batch",
    "AuctionResult",
    "make_eps_schedule",
]
