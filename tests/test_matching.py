"""Matching-layer tests: greedy / Hungarian / auction vs the scipy oracle."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from scipy.optimize import linear_sum_assignment

from repro.core.matching import (auction_batch, auction_score_bounds,
                                 greedy_matching_score, hungarian_batch,
                                 hungarian_score, make_eps_schedule)


def _oracle(w):
    ri, ci = linear_sum_assignment(-w)
    return float(w[ri, ci].sum())


def _random_weights(rng, nq, nc, thresh):
    w = rng.random((nq, nc)).astype(np.float32)
    return np.where(w >= thresh, w, 0.0)


# ---------------------------------------------------------------- hungarian
@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("shape", [(1, 1), (3, 7), (7, 3), (12, 12)])
def test_hungarian_exact(seed, shape):
    rng = np.random.default_rng(seed)
    w = _random_weights(rng, *shape, thresh=0.5)
    assert abs(float(hungarian_score(jnp.asarray(w))) - _oracle(w)) < 1e-4


def test_hungarian_batch_padded():
    rng = np.random.default_rng(3)
    B, N, M = 6, 10, 14
    w = np.zeros((B, N, M), np.float32)
    nqs = rng.integers(1, N + 1, B).astype(np.int32)
    ncs = rng.integers(1, M + 1, B).astype(np.int32)
    oracles = []
    for b in range(B):
        wb = _random_weights(rng, nqs[b], ncs[b], 0.6)
        w[b, :nqs[b], :ncs[b]] = wb
        oracles.append(_oracle(wb))
    so, _ = hungarian_batch(jnp.asarray(w), jnp.asarray(nqs),
                            jnp.asarray(ncs))
    np.testing.assert_allclose(np.asarray(so), oracles, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 9), st.integers(1, 9))
def test_hungarian_property(seed, nq, nc):
    rng = np.random.default_rng(seed)
    w = _random_weights(rng, nq, nc, 0.4)
    assert abs(float(hungarian_score(jnp.asarray(w))) - _oracle(w)) < 1e-4


# ------------------------------------------------------------------- greedy
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 9), st.integers(1, 9))
def test_greedy_bounds(seed, nq, nc):
    """Greedy is a lower bound and a 1/2-approximation (Lemma 3)."""
    rng = np.random.default_rng(seed)
    w = _random_weights(rng, nq, nc, 0.3)
    so = _oracle(w)
    g = float(greedy_matching_score(jnp.asarray(w)))
    assert g <= so + 1e-5
    assert g >= so / 2 - 1e-5


# ------------------------------------------------------------------ auction
def test_auction_exact_brackets():
    rng = np.random.default_rng(0)
    for _ in range(10):
        nq, nc = rng.integers(1, 16, 2)
        w = _random_weights(rng, nq, nc, 0.6)
        so = _oracle(w)
        lb, ub = auction_score_bounds(w, eps_min=1e-4)
        K = max(nq, nc)
        assert float(lb) <= so + 1e-4
        assert float(ub) >= so - 1e-4
        assert float(ub) - float(lb) <= K * 2e-4 + 1e-4


def test_auction_early_termination_lemma8():
    """theta_lb above every SO -> every matching aborted with a certificate."""
    rng = np.random.default_rng(1)
    B, N, M = 4, 12, 12
    w = np.stack([_random_weights(rng, N, M, 0.6) for _ in range(B)])
    nqs = np.full(B, N, np.int32)
    ncs = np.full(B, M, np.int32)
    res = auction_batch(jnp.asarray(w), jnp.asarray(nqs), jnp.asarray(ncs),
                        make_eps_schedule(1e-4), jnp.float32(1e9))
    assert bool(np.all(np.asarray(res.early_stopped)))
    # the certificate: dual bound below theta at abort
    assert bool(np.all(np.asarray(res.ub) < 1e9))


def test_auction_dual_always_upper_bound():
    """ub >= SO even when theta_lb triggers early termination mid-way."""
    rng = np.random.default_rng(2)
    for _ in range(5):
        nq, nc = rng.integers(3, 12, 2)
        w = _random_weights(rng, nq, nc, 0.5)
        so = _oracle(w)
        # theta slightly below SO: must NOT abort (ub never sinks below SO)
        lb, ub = auction_score_bounds(w, eps_min=1e-4, theta_lb=so - 0.05)
        assert float(ub) >= so - 1e-4
        assert float(lb) <= so + 1e-4


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 12), st.integers(1, 24))
def test_auction_nq_bounded_exact_vs_hungarian(seed, nq, nc):
    """Guards the nq-row auction (only logical |Q| rows bid; release-with-
    price-zeroing phase transitions): brackets must contain the exact
    Hungarian SO and be nq-tight — the bracket's eps-CS slack is one eps
    per LOGICAL row, with no unassigned-price leftover."""
    rng = np.random.default_rng(seed)
    w = _random_weights(rng, nq, nc, 0.5)
    N, M = max(nq, 4), max(nc, 4)          # padded shapes, like the pool's
    wp = np.zeros((N, M), np.float32)
    wp[:nq, :nc] = w
    so, _ = hungarian_batch(jnp.asarray(wp)[None],
                            jnp.asarray([nq], jnp.int32),
                            jnp.asarray([nc], jnp.int32))
    so = float(so[0])
    res = auction_batch(jnp.asarray(wp)[None], jnp.asarray([nq], jnp.int32),
                        jnp.asarray([nc], jnp.int32),
                        make_eps_schedule(1e-4), jnp.float32(-1e30))
    lb, ub = float(res.lb[0]), float(res.ub[0])
    assert lb <= so + 1e-4 <= ub + 2e-4
    assert ub - lb <= nq * 2e-4 + 1e-4     # nq-bounded slack, NOT max(N, M)


def test_auction_rounds_bounded_by_logical_rows():
    """The square-padding round cost is gone: a |Q|=1 verification against
    a wide padded matrix converges in O(phases) rounds, not O(K)."""
    rng = np.random.default_rng(9)
    K = 64
    w = np.zeros((1, K, K), np.float32)
    w[0, 0] = np.where(rng.random(K) >= 0.5, rng.random(K), 0.0)
    res = auction_batch(jnp.asarray(w), jnp.asarray([1], jnp.int32),
                        jnp.asarray([K], jnp.int32),
                        make_eps_schedule(1e-4), jnp.float32(-1e30))
    assert int(res.rounds[0]) < K // 2     # historical form needed >= K


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 10), st.integers(1, 10))
def test_auction_vs_scipy(seed, nq, nc):
    """Guards the square/perfect-matching reduction (DESIGN.md §2): the
    asymmetric dummy-sink form breaks eps-scaling price carryover."""
    rng = np.random.default_rng(seed)
    w = _random_weights(rng, nq, nc, 0.5)
    so = _oracle(w)
    lb, ub = auction_score_bounds(w, eps_min=1e-4)
    assert float(lb) <= so + 1e-4 <= float(ub) + 2e-4
    assert float(ub) - float(lb) <= max(nq, nc) * 2e-4 + 1e-4
