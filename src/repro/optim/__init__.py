from .adamw import AdamWConfig, init as adamw_init, update as adamw_update
from .schedule import warmup_cosine, wsd
from .grad import (accumulate, clip_by_global_norm, compress, decompress,
                   global_norm, zero_residual)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "warmup_cosine",
           "wsd", "accumulate", "clip_by_global_norm", "compress",
           "decompress", "global_norm", "zero_residual"]
