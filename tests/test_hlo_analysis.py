"""HLO collective census + roofline-term math + blocked-xent numerics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import blocked_xent, softmax_xent
from repro.runtime.hlo_analysis import (CollectiveStats,
                                        normalize_cost_analysis,
                                        parse_collectives,
                                        roofline_terms, PEAK_FLOPS, HBM_BW,
                                        ICI_BW)

_FAKE_HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %x = f32[16,256]{1,0} convert(%p0)
  %ag = f32[16,4096]{1,0} all-gather(%x), dimensions={1}
  %ar = f32[16,256]{1,0} all-reduce(%x), to_apply=add
  %cp = f32[16,256]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
}
"""


def test_parse_collectives_counts_and_bytes():
    st_ = parse_collectives(_FAKE_HLO)
    assert st_.counts["all-gather"] == 1
    assert st_.counts["all-reduce"] == 1
    assert st_.counts["collective-permute"] == 1
    x_bytes = 16 * 256 * 4
    # operand of all three ops is %x
    assert st_.operand_bytes["all-gather"] == x_bytes
    assert st_.operand_bytes["all-reduce"] == x_bytes
    # ring model: all-reduce counts 2x
    assert st_.link_bytes() == x_bytes * (1 + 2 + 1)


def test_roofline_terms_bottleneck():
    t = roofline_terms(flops=PEAK_FLOPS, bytes_accessed=HBM_BW / 2,
                       link_bytes=ICI_BW / 4)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert t["bottleneck"] == "compute"
    assert abs(t["roofline_fraction"] - 1.0) < 1e-9
    t2 = roofline_terms(flops=PEAK_FLOPS / 100, bytes_accessed=HBM_BW,
                        link_bytes=0)
    assert t2["bottleneck"] == "memory"
    assert t2["roofline_fraction"] < 0.02


def test_real_compiled_module_parses():
    """The census runs on an actual compiled jax module without error."""
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    stats = parse_collectives(c.as_text())
    assert stats.total_operand_bytes == 0    # single device: no collectives


def test_normalize_cost_analysis_shapes():
    """Regression: ``Compiled.cost_analysis()`` is a flat dict on older
    JAX, a list of per-executable dicts on newer versions, or None."""
    d = {"flops": 7.0, "bytes accessed": 3.0}
    assert normalize_cost_analysis(d) == d
    assert normalize_cost_analysis([d]) == d              # new list shape
    assert normalize_cost_analysis([{}, d]) == d          # skips empties
    assert normalize_cost_analysis(None) == {}
    assert normalize_cost_analysis([]) == {}
    with pytest.raises(TypeError):
        normalize_cost_analysis(42)


def test_normalize_cost_analysis_live():
    """Whatever this JAX version returns normalizes to a flops dict."""
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    ca = normalize_cost_analysis(c.cost_analysis())
    assert ca.get("flops", 0) > 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(2, 7),
       st.sampled_from([16, 32, 64]))
def test_blocked_xent_matches_dense(seed, B, S, block):
    """Property: streamed-LSE blocked loss == dense loss (fwd + grad)."""
    rng = np.random.default_rng(seed)
    d, V = 8, int(rng.integers(10, 90))
    x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    tbl = jnp.asarray(rng.normal(size=(V, d)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)

    def dense(x, t):
        logits = (x.astype(jnp.float32).reshape(B * S, d)
                  @ t.astype(jnp.float32).T).reshape(B, S, V)
        return softmax_xent(logits, lab)

    def blocked(x, t):
        return blocked_xent(x, t, lab, block=block)

    np.testing.assert_allclose(float(dense(x, tbl)), float(blocked(x, tbl)),
                               rtol=1e-5)
    g1 = jax.grad(dense, argnums=1)(x, tbl)
    g2 = jax.grad(blocked, argnums=1)(x, tbl)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)
