"""Fault tolerance & elasticity: the control-plane state machine.

On real multi-host TPU fleets, failure detection is heartbeat-driven and
the recovery path is: quiesce -> choose largest healthy mesh -> restore the
latest checkpoint with the new sharding -> resume (the data pipeline is a
pure function of the step counter, so no data is lost or repeated).  This
module implements that state machine host-side so it is unit-testable in
this single-process container; the mesh-building and resharding pieces it
drives (launch/mesh.py, checkpoint/) are the real ones.

Straggler mitigation: per-step host heartbeats; hosts whose step latency
exceeds ``straggler_factor`` x the fleet median for ``patience``
consecutive steps are reported for eviction (the same quiesce/re-mesh path
as a failure, minus the lost shard)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    last_step: int
    step_latency: float = 0.0
    healthy: bool = True


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    heartbeat_timeout: float = 60.0
    straggler_factor: float = 2.0
    straggler_patience: int = 3


class FleetMonitor:
    """Tracks host heartbeats; decides failure/straggler evictions and the
    replacement mesh shape."""

    def __init__(self, num_hosts: int, cfg: FaultConfig = FaultConfig(),
                 clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, clock(), -1) for h in range(num_hosts)}
        self._strag_count: Dict[int, int] = {h: 0 for h in range(num_hosts)}

    def heartbeat(self, host_id: int, step: int, step_latency: float):
        hs = self.hosts[host_id]
        hs.last_heartbeat = self.clock()
        hs.last_step = step
        hs.step_latency = step_latency

    def failed_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, hs in self.hosts.items()
                if hs.healthy and now - hs.last_heartbeat
                > self.cfg.heartbeat_timeout]

    def stragglers(self) -> List[int]:
        healthy = [hs for hs in self.hosts.values() if hs.healthy]
        lats = sorted(hs.step_latency for hs in healthy if hs.step_latency)
        if len(lats) < 2:
            return []
        median = lats[len(lats) // 2]
        out = []
        for hs in healthy:
            if hs.step_latency > self.cfg.straggler_factor * median:
                self._strag_count[hs.host_id] += 1
                if self._strag_count[hs.host_id] >= \
                        self.cfg.straggler_patience:
                    out.append(hs.host_id)
            else:
                self._strag_count[hs.host_id] = 0
        return out

    def evict(self, host_ids: List[int]):
        for h in host_ids:
            self.hosts[h].healthy = False
            self._strag_count[h] = 0

    def healthy_count(self) -> int:
        return sum(hs.healthy for hs in self.hosts.values())


def plan_elastic_mesh(healthy_chips: int,
                      model_axis: int) -> Optional[Tuple[int, ...]]:
    """Largest (data, model) mesh that fits the healthy chips, keeping the
    model axis intact (TP degree is fixed by the memory plan) and the data
    axis a power of two (keeps global batch divisible)."""
    if healthy_chips < model_axis:
        return None
    data = healthy_chips // model_axis
    p = 1
    while p * 2 <= data:
        p *= 2
    return (p, model_axis)


def resume_plan(monitor: FleetMonitor, chips_per_host: int,
                model_axis: int) -> dict:
    """The full recovery decision: who to evict, what mesh to rebuild,
    whether training can continue."""
    failed = monitor.failed_hosts()
    strag = monitor.stragglers()
    monitor.evict(failed + strag)
    chips = monitor.healthy_count() * chips_per_host
    mesh = plan_elastic_mesh(chips, model_axis)
    return {
        "evicted_failed": failed,
        "evicted_stragglers": strag,
        "healthy_chips": chips,
        "mesh": mesh,
        "action": "continue" if mesh else "halt",
    }
