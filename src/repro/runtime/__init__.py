from .sharding import (param_pspecs, opt_state_pspecs, input_pspecs,
                       to_shardings, fsdp_axes, dp_axes)
from .fault import (FleetMonitor, FaultConfig, plan_elastic_mesh,
                    resume_plan)

__all__ = ["param_pspecs", "opt_state_pspecs", "input_pspecs",
           "to_shardings", "fsdp_axes", "dp_axes", "FleetMonitor",
           "FaultConfig", "plan_elastic_mesh", "resume_plan",
           "RequestEngine", "EngineResponse", "AdmissionRouter",
           "ShardedCollection", "Shard", "CollectionEpoch",
           "CollectionUpdate", "UpdateValidationError"]


def __getattr__(name):
    # engine/collection import repro.core, which itself imports
    # repro.runtime.instrument — resolve these names lazily so
    # `import repro.core` never re-enters a half-initialized package
    if name in ("RequestEngine", "EngineResponse", "AdmissionRouter"):
        from . import engine
        return getattr(engine, name)
    if name in ("ShardedCollection", "Shard", "CollectionEpoch",
                "CollectionUpdate", "UpdateValidationError"):
        from . import collection
        return getattr(collection, name)
    raise AttributeError(name)
