"""Shared fixtures.  NOTE: no XLA_FLAGS manipulation here — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py (run as a
separate process) forces the 512-device host platform."""
import _hypothesis_compat  # noqa: F401 — installs a hypothesis shim if absent
import numpy as np
import pytest

from repro.core import EmbeddingSimilarity, SearchParams
from repro.data import make_collection, make_embeddings


@pytest.fixture(scope="session")
def small_world():
    """A small repository + clustered embeddings shared across tests."""
    coll = make_collection(num_sets=120, vocab_size=800, avg_size=8,
                           max_size=24, zipf_a=1.1, seed=7)
    emb = make_embeddings(800, dim=16, cluster_size=4.0, seed=7)
    return coll, EmbeddingSimilarity(emb)


@pytest.fixture(scope="session")
def default_params():
    return SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8)
