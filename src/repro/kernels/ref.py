"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _admit_level(st, do, l_q, l_slot, l_sim, Ci):
    """Admit one *level* of a chunk's set-segmented event layout: a lane
    vector of events that touch pairwise-distinct sets (each lane holds
    at most one event per set — the within-set rank defines the levels).

    Distinct sets make every scatter hit unique indices; padding lanes
    (``do`` False, set routed to index 0) contribute identity elements
    through commutative ops only (+0.0, +0, max False), so the fold is
    bit-identical to admitting the level's events one at a time in the
    serial per-event loop.
    """
    S, l, T, d, seen, qmatched, qseen, slot_matched = st
    qw = l_q >> 5
    bit = jnp.uint32(1) << (l_q & 31).astype(jnp.uint32)
    zero_u = jnp.uint32(0)

    # --- first-seen bookkeeping (sound iUB') ----------------------------
    first = do & ((qseen[Ci, qw] & bit) == 0)
    T = T.at[Ci].add(jnp.where(first, l_sim, 0.0))
    d = d.at[Ci].add(first.astype(jnp.int32))
    qseen = qseen.at[Ci, qw].add(jnp.where(first, bit, zero_u))
    seen = seen.at[Ci].max(do)

    # --- greedy admission (iLB, Lemma 5) --------------------------------
    q_free = (qmatched[Ci, qw] & bit) == 0
    adm = do & q_free & ~slot_matched[l_slot]
    S = S.at[Ci].add(jnp.where(adm, l_sim, 0.0))
    l = l.at[Ci].add(adm.astype(jnp.int32))
    qmatched = qmatched.at[Ci, qw].add(jnp.where(adm, bit, zero_u))
    slot_matched = slot_matched.at[l_slot].max(adm)
    return (S, l, T, d, seen, qmatched, qseen, slot_matched)


def refine_events_packed_ref(state, c_set, c_q, c_slot, c_sim):
    """Set-segmented greedy admission of one refinement chunk in the
    lane-PACKED (W, L) layout (the ``refine_events`` kernel's oracle and
    the standalone scan's production path).

    Row t holds level t of the chunk — the rank-``t`` event of every set
    that has one, compacted left into ``L`` pow2 lanes (``core.
    token_stream.pack_events_segmented``); -1 set ids pad.  Cross-set
    events commute (every mutated field is per-set and each flat slot
    belongs to exactly one set), so walking levels — ``depth`` = number
    of non-empty rows, sequential — while admitting each row as one
    L-wide vectorized scatter is bit-identical to the serial per-event
    loop (``tests/test_refinement_segmented.py``).

    state: (S, l, T, d, seen, alive, qmatched, qseen, slot_matched) —
    the per-set refinement carry minus theta (``alive`` is read-only
    here: the UB filter only runs at chunk boundaries).  Returns the
    mutated fields.
    """
    S, l, T, d, seen, alive, qmatched, qseen, slot_matched = state
    W = c_set.shape[0]
    row_live = jnp.any(c_set >= 0, axis=1)
    depth = jnp.max(jnp.where(
        row_live, jnp.arange(W, dtype=jnp.int32), -1)) + 1
    Ci_all = jnp.maximum(c_set, 0)
    # alive is chunk-constant (the UB filter runs at chunk boundaries):
    # gather it for every lane once, outside the level loop
    do_all = (c_set >= 0) & alive[Ci_all]

    def level(t, st):
        return _admit_level(st, do_all[t], c_q[t], c_slot[t], c_sim[t],
                            Ci_all[t])

    return jax.lax.fori_loop(
        0, depth, level,
        (S, l, T, d, seen, qmatched, qseen, slot_matched))


def refine_events_ref(state, c_set, c_q, c_slot, c_sim, c_rank):
    """Set-segmented admission of one chunk in the flat traced layout:
    events stay in stream order and ``c_rank`` carries each event's
    within-(chunk, set) occurrence index.  The scan walks rank levels —
    ``max rank + 1`` sequential steps — masking each level in place
    (full chunk width; the host path prefers the lane-packed form
    above, but in-trace consumers — the fused wave after device-side
    event expansion — cannot compact to data-dependent lane counts).
    Bit-identical to both the packed form and the serial loop."""
    S, l, T, d, seen, alive, qmatched, qseen, slot_matched = state
    valid = c_set >= 0
    Ci = jnp.maximum(c_set, 0)
    depth = jnp.max(jnp.where(valid, c_rank, -1)) + 1
    do_all = valid & alive[Ci]           # alive is chunk-constant

    def level(t, st):
        return _admit_level(st, do_all & (c_rank == t), c_q, c_slot,
                            c_sim, Ci)

    return jax.lax.fori_loop(
        0, depth, level,
        (S, l, T, d, seen, qmatched, qseen, slot_matched))


def event_ranks_ref(c_set: jnp.ndarray) -> jnp.ndarray:
    """Within-(chunk, set) occurrence index of each event — the traced
    mirror of ``core.token_stream.event_ranks`` for ONE chunk (the fused
    wave computes ranks in-trace after device-side event expansion).

    The stable sort keeps ties in stream order exactly like the host
    lexsort."""
    n = c_set.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    order = jnp.argsort(c_set, stable=True).astype(jnp.int32)
    ss = c_set[order]
    start = jnp.concatenate(
        [jnp.ones((1,), bool), ss[1:] != ss[:-1]])
    seg_start = jax.lax.cummax(jnp.where(start, iota, 0))
    rank_sorted = iota - seg_start
    return jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)


def cosine_topk_ref(qe: jnp.ndarray, ev: jnp.ndarray, k: int):
    """Full-matrix cosine scores + top-k per query row.

    qe: (nq, d) L2-normalized query embeddings.
    ev: (nv, d) L2-normalized vocabulary embeddings.
    Returns (vals (nq, k), idx (nq, k)) descending.
    """
    scores = qe @ ev.T
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def compact_indices_ref(mask: jnp.ndarray):
    """Prefix-sum compaction oracle: survivor indices ascending, -1 pad.

    mask: (n,) bool.  Returns (idx (n,) int32, count () int32) with
    idx[:count] == mask.nonzero()[0] and idx[count:] == -1.
    """
    n = mask.shape[0]
    m = mask.astype(jnp.int32)
    ps = jnp.cumsum(m)
    total = ps[-1] if n else jnp.int32(0)
    iota = jnp.arange(n, dtype=jnp.int32)
    pos = jnp.where(m > 0, ps - 1, total + iota - ps)
    idx = jnp.full((n,), -1, jnp.int32).at[pos].set(
        jnp.where(m > 0, iota, jnp.int32(-1)))
    return idx, total.astype(jnp.int32)


def auction_topk2_ref(wm: jnp.ndarray, prices: jnp.ndarray):
    """Per-row best/second-best profit and best column (one auction round's
    heavy pass).  wm: (n, m); prices: (m,).  Returns (w1, w2, jstar)."""
    profits = wm - prices[None, :]
    w1 = jnp.max(profits, axis=1)
    jstar = jnp.argmax(profits, axis=1).astype(jnp.int32)
    cols = jnp.arange(wm.shape[1])
    second = jnp.where(cols[None, :] == jstar[:, None], -jnp.inf, profits)
    w2 = jnp.max(second, axis=1)
    return w1, w2, jstar


def ssd_ref(x, dt, A, B, C, D, chunk: int = 0):
    """Mamba2 SSD (state-space duality) sequential-scan oracle.

    Shapes (single sequence):
      x:  (L, H, P)    input heads (P = head dim)
      dt: (L, H)       softplus-ed timestep per head
      A:  (H,)         negative state decay per head (A < 0)
      B:  (L, G, S)    input->state projection (G state groups, S = state dim)
      C:  (L, G, S)    state->output projection
      D:  (H,)         skip connection
    Heads are grouped: head h uses group h % G.
    Returns y: (L, H, P).

    Recurrence (per head h, group g = h % G):
      S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t (outer) x_t
      y_t = C_t . S_t + D_h * x_t
    """
    L, H, P = x.shape
    G = B.shape[1]
    S = B.shape[2]

    def step(carry, t):
        st = carry                                 # (H, P, S)
        dta = jnp.exp(dt[t][:, None, None] * A[:, None, None])  # (H,1,1)
        Bg = B[t][jnp.arange(H) % G]               # (H, S)
        Cg = C[t][jnp.arange(H) % G]               # (H, S)
        upd = dt[t][:, None, None] * x[t][:, :, None] * Bg[:, None, :]
        st = dta * st + upd                        # (H, P, S)
        y = jnp.einsum("hps,hs->hp", st, Cg) + D[:, None] * x[t]
        return st, y

    st0 = jnp.zeros((H, P, S), x.dtype)
    _, ys = jax.lax.scan(step, st0, jnp.arange(L))
    return ys


def flash_attention_ref(q, k, v, causal: bool = True):
    """Dense softmax(QK^T/sqrt(d))V oracle.  q,k,v: (B,H,S,d)."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * d ** -0.5
    if causal:
        S = q.shape[2]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
