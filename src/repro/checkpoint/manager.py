"""Rolling checkpoint manager: step-numbered checkpoints + metadata,
restore-latest, retention, preemption safety (restart resumes mid-run)."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

from .checkpoint import AsyncSaver, restore, save

_PAT = re.compile(r"ckpt_(\d+)\.zst$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._saver = AsyncSaver() if async_save else None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:09d}.zst")

    def steps(self):
        out = []
        for f in os.listdir(self.dir):
            m = _PAT.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        meta = dict(metadata or {})
        meta["step"] = step
        payload = {"meta": meta, "state": tree}
        if self._saver is not None:
            self._saver.save(self._path(step), payload)
        else:
            save(self._path(step), payload)
        self._gc()

    def restore_latest(self):
        """Returns (step, state, meta) or None."""
        step = self.latest_step()
        if step is None:
            return None
        self.wait()
        payload = restore(self._path(step))
        return step, payload["state"], payload["meta"]

    def wait(self):
        if self._saver is not None:
            self._saver.wait()

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            try:
                os.unlink(self._path(s))
            except OSError:
                pass
