"""Architecture registry: --arch <id> -> config module."""
from __future__ import annotations

import importlib

ARCHS = {
    "zamba2-2.7b": "zamba2_2p7b",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "granite-34b": "granite_34b",
    "minitron-8b": "minitron_8b",
    "qwen3-8b": "qwen3_8b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "mamba2-130m": "mamba2_130m",
    "internvl2-1b": "internvl2_1b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()


def list_archs():
    return list(ARCHS)
