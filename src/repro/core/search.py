"""KoiosSearch — end-to-end top-k semantic overlap search (paper Fig. 2).

Single-partition pipeline:
    token stream (blocked sim matmul)  ->  event expansion (inverted index)
    ->  refinement (chunked vectorized filters)  ->  post-processing
    (No-EM + batched verification w/ Lemma-8 early termination).

Multi-query serving: ``KoiosSearch.search_batch`` fuses B queries through
the same pipeline — one stacked similarity sweep per partition and a shared
cross-query verification queue (``run_postprocess_batch``) — returning
results bit-identical to per-query ``search``.

Partitioned scale-out (paper §VI last paragraph): the repository is split
into contiguous shards; every shard runs refinement + post-processing with
a *shared* theta_lb (the max over shards — on a device mesh this is an
all-reduce-max, see ``repro.launch.serve`` / ``repro.runtime.sharding``),
and the per-shard top-k lists are merged.  This module provides the
host-level reference implementation (exactly the paper's semantics); the
mesh-parallel execution path reuses the same per-shard functions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .inverted_index import InvertedIndex
from .postprocess import (PostprocessState, run_postprocess,
                          run_postprocess_batch)
from .refinement import run_refinement, run_refinement_batch
from .token_stream import (build_token_stream, build_token_stream_batch,
                           expand_to_events)
from .types import SearchParams, SearchResult, SearchStats, SetCollection


@dataclasses.dataclass
class KoiosIndex:
    """Prebuilt indexes for one partition of the repository."""

    coll: SetCollection
    inv: InvertedIndex
    id_offset: int = 0      # global id of the partition's first set

    @staticmethod
    def build(coll: SetCollection, id_offset: int = 0) -> "KoiosIndex":
        return KoiosIndex(coll=coll, inv=InvertedIndex.build(coll),
                          id_offset=id_offset)


def search_partition(index: KoiosIndex, query: np.ndarray, sim_provider,
                     params: SearchParams,
                     theta_lb0: float = 0.0) -> SearchResult:
    """Run KOIOS on one partition; ``theta_lb0`` is the shared global bound."""
    coll = index.coll
    query = np.asarray(query, dtype=np.int32)
    stream = build_token_stream(query, sim_provider, params.alpha)
    events = expand_to_events(stream, index.inv)

    if len(events) == 0:
        return _empty_result()

    ref = run_refinement(
        events, coll.set_sizes, len(query), coll.total_tokens,
        params.k, params.alpha, params.chunk_size, params.ub_mode)
    ref.theta_lb = max(ref.theta_lb, theta_lb0)

    surv = (ref.seen & ref.alive).nonzero()[0]
    result = run_postprocess(
        coll, query, sim_provider, surv, ref.S[surv], ref.ub[surv],
        ref.theta_lb, params, ref.stats)
    return SearchResult(
        ids=(result.ids + index.id_offset).astype(np.int32),
        lb=result.lb, ub=result.ub, stats=result.stats)


def _empty_result() -> SearchResult:
    return SearchResult(
        ids=np.zeros(0, np.int32), lb=np.zeros(0, np.float32),
        ub=np.zeros(0, np.float32), stats=SearchStats())


def search_partition_batch(index: KoiosIndex, queries: Sequence[np.ndarray],
                           sim_provider, params: SearchParams,
                           theta_lb0s: Sequence[float]
                           ) -> "list[SearchResult]":
    """Batched :func:`search_partition`: B queries against one partition.

    The token stream is built for all queries with one blocked sweep,
    refinement runs per query (reusing one jit cache), and post-processing
    advances all queries in lock step over a shared verification queue.
    Per-query results are bit-identical to B :func:`search_partition` calls.
    """
    coll = index.coll
    queries = [np.asarray(q, dtype=np.int32) for q in queries]
    streams = build_token_stream_batch(queries, sim_provider, params.alpha)
    results: "list[Optional[SearchResult]]" = [None] * len(queries)
    live_pos, live_queries, live_events = [], [], []
    for i, (query, stream) in enumerate(zip(queries, streams)):
        events = expand_to_events(stream, index.inv)
        if len(events) == 0:
            results[i] = _empty_result()
            continue
        live_pos.append(i)
        live_queries.append(query)
        live_events.append(events)
    refs = run_refinement_batch(
        live_events, live_queries, coll.set_sizes, coll.total_tokens,
        params.k, params.alpha, params.chunk_size, params.ub_mode)
    states, state_pos = [], []
    for i, query, ref in zip(live_pos, live_queries, refs):
        ref.theta_lb = max(ref.theta_lb, float(theta_lb0s[i]))
        surv = (ref.seen & ref.alive).nonzero()[0]
        states.append(PostprocessState(
            query, surv, ref.S[surv], ref.ub[surv], ref.theta_lb, params,
            ref.stats))
        state_pos.append(i)
    for i, r in zip(state_pos,
                    run_postprocess_batch(coll, sim_provider, states,
                                          params)):
        results[i] = SearchResult(
            ids=(r.ids + index.id_offset).astype(np.int32),
            lb=r.lb, ub=r.ub, stats=r.stats)
    return results


def merge_topk(results: Sequence[SearchResult], k: int) -> SearchResult:
    """Merge per-partition top-k lists (paper: 'merge-sorted')."""
    ids = np.concatenate([r.ids for r in results])
    lb = np.concatenate([r.lb for r in results])
    ub = np.concatenate([r.ub for r in results])
    order = np.argsort(-lb, kind="stable")[:k]
    stats = SearchStats()
    for r in results:
        for f, v in r.stats.as_dict().items():
            setattr(stats, f, getattr(stats, f) + v if f != "theta_lb_final"
                    else max(getattr(stats, f), v))
    return SearchResult(ids=ids[order], lb=lb[order], ub=ub[order],
                        stats=stats)


class KoiosSearch:
    """Public search API over a (possibly partitioned) repository."""

    def __init__(self, coll: SetCollection, sim_provider,
                 params: Optional[SearchParams] = None,
                 partitions: int = 1):
        self.params = params or SearchParams()
        self.sim = sim_provider
        self.partitions = []
        n = coll.num_sets
        bounds = np.linspace(0, n, partitions + 1).astype(int)
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            if hi > lo:
                self.partitions.append(
                    KoiosIndex.build(coll.slice_sets(int(lo), int(hi)),
                                     id_offset=int(lo)))

    def search(self, query: np.ndarray, k: Optional[int] = None) -> SearchResult:
        params = self.params if k is None else dataclasses.replace(
            self.params, k=k)
        theta_lb = 0.0
        results = []
        # Sequential host loop over partitions sharing theta_lb (the mesh
        # execution path runs these concurrently with an all-reduce-max;
        # sharing the running max here mirrors the paper's shared bound).
        for part in self.partitions:
            r = search_partition(part, query, self.sim, params, theta_lb)
            results.append(r)
            if len(r.lb) >= params.k:
                theta_lb = max(theta_lb, float(r.lb[params.k - 1]))
        return merge_topk(results, params.k)

    def search_batch(self, queries: Sequence[np.ndarray],
                     k: Optional[int] = None) -> "list[SearchResult]":
        """Batched multi-query search — one fused pipeline for B queries.

        Semantically equivalent to ``[self.search(q) for q in queries]``
        (bit-identical ids/lb/ub) but executes the similarity sweep and all
        verification batches across queries together: one blocked
        (sum |Q_b| x |V|) matmul per vocab block and a shared cross-query
        verification queue per partition (see ``core.postprocess``).
        """
        params = self.params if k is None else dataclasses.replace(
            self.params, k=k)
        queries = [np.asarray(q, dtype=np.int32) for q in queries]
        theta_lb = [0.0] * len(queries)
        per_query: "list[list[SearchResult]]" = [[] for _ in queries]
        # Partitions stay sequential, sharing each query's running theta_lb
        # exactly as in `search` (the mesh path all-reduces this bound).
        for part in self.partitions:
            results = search_partition_batch(part, queries, self.sim,
                                             params, theta_lb)
            for i, r in enumerate(results):
                per_query[i].append(r)
                if len(r.lb) >= params.k:
                    theta_lb[i] = max(theta_lb[i],
                                      float(r.lb[params.k - 1]))
        return [merge_topk(rs, params.k) for rs in per_query]
