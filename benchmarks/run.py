"""Benchmark entrypoint: one function per paper table/figure.

``python -m benchmarks.run`` runs everything at CPU-feasible scale and
prints ``name,us_per_call,derived`` CSV lines plus the per-table reports.
``--only <name>`` runs a single benchmark; ``--fast`` trims query counts."""
from __future__ import annotations

import argparse
import time


def _banner(name):
    print(f"\n===== {name} " + "=" * max(0, 60 - len(name)), flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "pruning", "response", "parameters",
                             "quality", "kernels", "roofline", "soak"])
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.time()
    want = lambda n: args.only in (None, n)   # noqa: E731

    if want("kernels"):
        _banner("kernel microbench (us/call)")
        from . import kernels
        kernels.main([])

    if want("pruning"):
        _banner("Table II: filter pruning power")
        from . import pruning_power
        print("dataset,interval,candidates,iUB%,No-EM,EM-early,EM,verified%")
        for r in pruning_power.run(n_queries=2):
            print(f"{r['dataset']},{r['interval']},{r['candidates']:.0f},"
                  f"{r['refine_prune_pct']:.1f},{r['no_em']:.1f},"
                  f"{r['em_early']:.1f},{r['em_full']:.1f},"
                  f"{r['verified_pct']:.2f}")
        if not args.fast:
            _banner("Tables IV/V: pruning by query cardinality (opendata)")
            for r in pruning_power.run(datasets=("opendata",),
                                       by_cardinality=True, n_queries=2):
                print(f"{r['dataset']},{r['interval']},"
                      f"cand={r['candidates']:.0f},"
                      f"iUB%={r['refine_prune_pct']:.1f},"
                      f"verified%={r['verified_pct']:.2f}")

    if want("response"):
        _banner("Table III: response time vs baselines")
        from . import response_time
        print("dataset,sim,koios_s,baseline_s,baseline+_s,speedup,"
              "em_koios,em_baseline,mem_mb")
        for r in response_time.run(n_queries=2):
            print(f"{r['dataset']},{r['sim']},{r['koios_s']:.2f},"
                  f"{r['baseline_s']:.2f},{r['baseline_plus_s']:.2f},"
                  f"{r['speedup']:.1f},{r['em_koios']:.0f},"
                  f"{r['em_baseline']:.0f},{r['mem_mb']:.1f}")
        _banner("Scale-out: overlapped scheduler vs sequential partitions")
        print("dataset,partitions,sequential_s,overlap_s,speedup,"
              "bound_raises,backward_raises")
        r = response_time.run_partition_ab(
            partitions=4, batch_size=4 if args.fast else 8)
        print(f"{r['dataset']},{r['partitions']},{r['sequential_s']:.4f},"
              f"{r['overlap_s']:.4f},{r['speedup']:.2f},"
              f"{r['bound_raises']},{r['backward_raises']}")
        _banner("Fused wave: on-device schedule vs host-driven overlap")
        print("dataset,partitions,overlap_s,fused_s,speedup,"
              "overlap_transfers,fused_transfers,result_hash")
        rf = response_time.run_fused_ab(
            partitions=4, batch_size=4 if args.fast else 8)
        print(f"{rf['dataset']},{rf['partitions']},{rf['overlap_s']:.4f},"
              f"{rf['fused_s']:.4f},{rf['speedup']:.2f},"
              f"{rf['overlap_transfers']},{rf['fused_transfers']},"
              f"{rf['result_hash']}")
        _banner("Request engine: continuous batching vs per-batch loop")
        print("dataset,partitions,batch_loop_s,engine_s,speedup,"
              "cache_hit_rate,mean_queue_depth")
        re_ = response_time.run_engine_ab(
            partitions=4, batch_size=4 if args.fast else 8,
            n_requests=8 if args.fast else 16,
            stagger_ms=10.0 if args.fast else 25.0)
        print(f"{re_['dataset']},{re_['partitions']},"
              f"{re_['batch_loop_s']:.4f},{re_['engine_s']:.4f},"
              f"{re_['speedup']:.2f},{re_['cache_hit_rate']:.2f},"
              f"{re_['mean_queue_depth']:.1f}")
        _banner("Sharded collection: N-shard resource vs 1-shard reference")
        print("dataset,shards,devices,one_shard_s,sharded_s,speedup,"
              "result_hash")
        rs = response_time.run_sharded_ab(
            shards=4, batch_size=4 if args.fast else 8)
        print(f"{rs['dataset']},{rs['shards']},{rs['devices']},"
              f"{rs['one_shard_s']:.4f},{rs['sharded_s']:.4f},"
              f"{rs['speedup']:.2f},{rs['result_hash']}")
        response_time.write_bench_json({
            "partition_ab": r, "fused_ab": rf, "engine_ab": re_,
            "sharded_ab": rs,
        }, "BENCH_response_time.json", "suite")
        if not args.fast:
            _banner("SilkMoth-mode (char n-gram similarity, §VIII-B)")
            for r in response_time.run(datasets=("opendata",),
                                       sim_kind="ngram",
                                       include_baseline=False):
                print(f"{r['dataset']},ngram,koios_s={r['koios_s']:.2f}")

    if want("soak"):
        _banner("Fault-injected soak: failover + deadline shedding")
        from . import soak
        soak.main(["--fast"] if args.fast else [])

    if want("parameters"):
        _banner("Fig 7: parameter analysis")
        from . import parameters
        parameters.main()

    if want("quality"):
        _banner("Fig 8: semantic vs vanilla quality")
        from . import quality
        for r in quality.run(datasets=("dblp",), n_queries=2):
            print(f"{r['dataset']},{r['query']},{r['|Q|']},"
                  f"{r['kth_semantic']:.2f},{r['kth_vanilla']:.2f},"
                  f"{r['intersection']},{r['semantic_gain']:.2f}")

    if want("roofline"):
        _banner("Roofline table (from dry-run artifacts)")
        from . import roofline
        try:
            roofline.main()
        except Exception as e:                      # noqa: BLE001
            print(f"(no dry-run artifacts yet: {e})")

    print(f"\ntotal bench time: {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
