"""Serving driver: batched KOIOS search requests over a sharded corpus.

This is the paper's system as a service: the repository is sharded over the
(pod, data) mesh axes (paper §VI scale-out); each shard runs
refinement + post-processing with the *global* theta_lb (the all-reduce-max
of per-shard bounds — on the host reference path this is the running max),
and per-shard top-k lists are merged.  The embedding tower is any of the
assigned architectures (or the frozen-table provider standing in for
FastText).

Request batches run through the fused multi-query pipeline
(``KoiosSearch.search_batch``) by default; ``--per-query`` serves each
query independently (same results, the paper-style baseline).

Smoke scale:
    PYTHONPATH=src python -m repro.launch.serve --requests 4 --k 5
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from ..core import (EmbeddingSimilarity, KoiosSearch, SearchParams)
from ..data import (EmbeddingTableProvider, dataset_preset, make_embeddings,
                    sample_queries)


class SearchServer:
    """Batched request loop over a partitioned KOIOS engine.

    ``serve_batch`` runs the whole request batch through the fused
    multi-query pipeline (``KoiosSearch.search_batch``) by default: one
    stacked similarity sweep and a shared cross-query verification queue
    per partition.  ``batched=False`` falls back to the per-query loop
    (identical results — the A/B baseline of
    ``benchmarks/response_time.py``)."""

    def __init__(self, coll, sim, params: SearchParams, partitions: int):
        self.engine = KoiosSearch(coll, sim, params, partitions=partitions)

    def serve_batch(self, queries, batched: bool = True):
        """One batched request: list of query sets -> list of results."""
        queries = [np.asarray(q, np.int32) for q in queries]
        if batched:
            t0 = time.time()
            results = self.engine.search_batch(queries)
            lat = round((time.time() - t0) / max(len(queries), 1), 4)
            lats = [lat] * len(queries)       # amortized per-query latency
        else:
            results, lats = [], []
            for q in queries:
                t0 = time.time()
                results.append(self.engine.search(q))
                lats.append(round(time.time() - t0, 4))
        return [{
            "ids": res.ids.tolist(),
            "scores": res.lb.tolist(),
            "latency_s": lat,
            "stats": res.stats.as_dict(),
        } for res, lat in zip(results, lats)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="opendata")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=0.8)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--per-query", action="store_true",
                    help="serve each query independently (A/B baseline for "
                         "the default fused multi-query path)")
    args = ap.parse_args(argv)

    print(f"[serve] building corpus ({args.dataset} @ {args.scale})")
    coll = dataset_preset(args.dataset, scale=args.scale, seed=0)
    emb = make_embeddings(coll.vocab_size, dim=args.dim, seed=0)
    sim = EmbeddingTableProvider(emb)
    params = SearchParams(k=args.k, alpha=args.alpha)
    server = SearchServer(coll, sim, params, args.partitions)
    print(f"[serve] corpus: {coll.num_sets} sets, vocab {coll.vocab_size}, "
          f"{args.partitions} partitions")

    queries = sample_queries(coll, args.requests, seed=1)
    for lo in range(0, len(queries), args.batch_size):
        batch = queries[lo:lo + args.batch_size]
        results = server.serve_batch(batch, batched=not args.per_query)
        for i, r in enumerate(results):
            print(f"req {lo+i}: top-{args.k} ids={r['ids'][:5]}... "
                  f"scores={[round(s,2) for s in r['scores'][:5]]} "
                  f"lat={r['latency_s']}s "
                  f"verified={r['stats']['exact_matches']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
