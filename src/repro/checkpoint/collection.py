"""Crash-consistent collection snapshots: per-shard payloads + an atomic
epoch manifest.

The live-update plane (DESIGN.md §6.5) makes the repository mutable; this
module makes it durable.  A snapshot is

  ``shard_<sha16>.msgpack``   one content-addressed payload per distinct
                              shard body: {set_indptr, set_tokens,
                              vocab_size}, written through
                              :func:`repro.checkpoint.save` (itself
                              mkstemp + ``os.replace``, so a payload file
                              is whole or absent).  The address is a
                              sha256 over the CSR bytes + vocab —
                              deliberately EXCLUDING the global id offset,
                              so a copy-on-write-shared shard whose offset
                              shifted across a commit dedupes to the same
                              file, and consecutive snapshots rewrite only
                              rebuilt shards.
  ``MANIFEST.json``           the epoch commit point: epoch number, global
                              geometry, and the ordered shard list
                              (payload file, sha, id_offset, set count).

Ordering is the crash-consistency argument: payloads first, manifest LAST
via write-temp-then-``os.replace`` (atomic on POSIX).  A crash before the
rename leaves the previous manifest intact (restore sees the OLD epoch;
orphan payloads are garbage, collected on the next save); a crash after
leaves the new manifest referencing fully-written payloads (restore sees
the NEW epoch).  There is no interleaving that yields a torn mix —
tests/test_collection_epoch.py simulates the mid-commit crash and asserts
old-or-new, and corrupts a payload on disk to assert the sha check turns
silent corruption into :class:`SnapshotCorruptionError`.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Optional

import numpy as np

from .checkpoint import restore as _restore_tree
from .checkpoint import save as _save_tree

MANIFEST = "MANIFEST.json"
_FORMAT = "koios-collection-v1"


class SnapshotCorruptionError(RuntimeError):
    """A snapshot payload failed its content-hash check on restore."""


def _shard_sha(set_indptr: np.ndarray, set_tokens: np.ndarray,
               vocab_size: int) -> str:
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(set_indptr, np.int64).tobytes())
    h.update(np.ascontiguousarray(set_tokens, np.int32).tobytes())
    h.update(str(int(vocab_size)).encode())
    return h.hexdigest()


class CollectionSnapshotter:
    """Save/restore a :class:`~repro.runtime.collection.ShardedCollection`
    head epoch under one directory, crash-consistently."""

    def __init__(self, directory: str):
        self.directory = str(directory)

    # ------------------------------------------------------------- save
    def save(self, collection) -> dict:
        """Snapshot the head epoch: payloads, then the manifest
        (atomic), then GC of unreferenced payloads.  Returns the
        manifest written."""
        head = collection.head
        manifest = self._write_payloads(head)
        self._install_manifest(manifest)
        self._gc(manifest)
        return manifest

    def _write_payloads(self, head) -> dict:
        """Write every shard payload (content-addressed; skipped when the
        file already exists) and return the manifest that references
        them.  Split from :meth:`_install_manifest` so tests can crash
        the process between the two phases."""
        os.makedirs(self.directory, exist_ok=True)
        shards = []
        for s in head.shards:
            c = s.coll
            sha = _shard_sha(c.set_indptr, c.set_tokens, c.vocab_size)
            fname = f"shard_{sha[:16]}.msgpack"
            path = os.path.join(self.directory, fname)
            if not os.path.exists(path):
                _save_tree(path, {
                    "set_indptr": np.asarray(c.set_indptr, np.int64),
                    "set_tokens": np.asarray(c.set_tokens, np.int32),
                    "vocab_size": int(c.vocab_size),
                })
            shards.append({"file": fname, "sha": sha,
                           "id_offset": int(s.id_offset),
                           "sets": int(c.num_sets)})
        return {
            "format": _FORMAT,
            "epoch": int(head.epoch),
            "vocab_size": int(head.coll.vocab_size),
            "num_sets": int(head.coll.num_sets),
            "shards": shards,
        }

    def _install_manifest(self, manifest: dict) -> None:
        """The commit point: temp file + ``os.replace`` onto MANIFEST.
        Everything before this is invisible to restore; everything after
        is fully referenced."""
        fd, tmp = tempfile.mkstemp(dir=self.directory,
                                   prefix=".manifest.", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(manifest, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, os.path.join(self.directory, MANIFEST))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _gc(self, manifest: dict) -> None:
        """Drop payload files the installed manifest no longer references
        (retired epochs' rebuilt shards, crashed saves' orphans)."""
        live = {s["file"] for s in manifest["shards"]}
        for name in os.listdir(self.directory):
            if (name.startswith("shard_") and name.endswith(".msgpack")
                    and name not in live):
                try:
                    os.unlink(os.path.join(self.directory, name))
                except OSError:
                    pass

    # ---------------------------------------------------------- restore
    def restore(self, devices=None):
        """Rebuild the snapshotted collection (same shard split, same
        epoch number) or ``None`` when no manifest exists.  Every payload
        is re-hashed against its manifest sha — bit-level corruption
        raises :class:`SnapshotCorruptionError` rather than serving wrong
        top-k.  ``devices`` re-places shards like ``build`` (placement is
        host policy, not snapshot state)."""
        from ..core.inverted_index import InvertedIndex
        from ..core.types import SetCollection
        from ..runtime.collection import Shard, ShardedCollection

        mpath = os.path.join(self.directory, MANIFEST)
        if not os.path.exists(mpath):
            return None
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("format") != _FORMAT:
            raise SnapshotCorruptionError(
                f"unknown snapshot format {manifest.get('format')!r}")
        if devices == "auto":
            import jax

            devices = jax.devices()
        shards = []
        colls = []
        for sid, entry in enumerate(manifest["shards"]):
            path = os.path.join(self.directory, entry["file"])
            if not os.path.exists(path):
                raise SnapshotCorruptionError(
                    f"manifest references missing payload {entry['file']}")
            tree = _restore_tree(path)
            c = SetCollection(
                set_indptr=np.asarray(tree["set_indptr"], np.int64),
                set_tokens=np.asarray(tree["set_tokens"], np.int32),
                vocab_size=int(tree["vocab_size"]))
            sha = _shard_sha(c.set_indptr, c.set_tokens, c.vocab_size)
            if sha != entry["sha"]:
                raise SnapshotCorruptionError(
                    f"payload {entry['file']} content hash mismatch "
                    f"(snapshot corrupted)")
            if c.num_sets != entry["sets"]:
                raise SnapshotCorruptionError(
                    f"payload {entry['file']} set count "
                    f"{c.num_sets} != manifest {entry['sets']}")
            dev = devices[sid % len(devices)] if devices else None
            shards.append(Shard(
                coll=c, inv=InvertedIndex.build(c),
                id_offset=int(entry["id_offset"]), sid=sid, device=dev))
            colls.append(c)
        total = sum(c.num_sets for c in colls)
        if total != manifest["num_sets"]:
            raise SnapshotCorruptionError(
                f"restored set count {total} != manifest "
                f"{manifest['num_sets']}")
        indptr = [np.zeros(1, np.int64)]
        tokens = []
        base = 0
        for c in colls:
            indptr.append(c.set_indptr[1:] + base)
            tokens.append(c.set_tokens)
            base += c.total_tokens
        coll = SetCollection(
            set_indptr=np.concatenate(indptr),
            set_tokens=(np.concatenate(tokens) if tokens
                        else np.zeros(0, np.int32)),
            vocab_size=int(manifest["vocab_size"]))
        return ShardedCollection(coll, shards,
                                 epoch=int(manifest["epoch"]))
