"""Continuous-batching request engine (DESIGN.md §3.2): staggered
arrivals with mid-flight joins are bit-identical to the one-shot
``search_batch`` path across schedules x partitions x verifiers; the
stream cache serves bit-identical streams on hits and evicts LRU; the
engine reports true per-request lifecycle timings."""
import numpy as np
import pytest

from repro.core import (KoiosSearch, SearchParams, TokenStreamCache,
                        build_token_stream_batch,
                        build_token_stream_batch_cached)
from repro.data import sample_queries
from repro.launch.serve import SearchServer
from repro.runtime.engine import RequestEngine


def _fake_clock():
    """Deterministic virtual clock: (now, advance, sleep)."""
    t = [1000.0]
    return (lambda: t[0],
            lambda dt: t.__setitem__(0, t[0] + dt),
            lambda dt: t.__setitem__(0, t[0] + dt))


def _params(verifier="hungarian", fused=False):
    return SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                        verifier=verifier,
                        fused="interpret" if fused else "auto")


@pytest.mark.parametrize("verifier", ["hungarian", "auction", "hybrid"])
@pytest.mark.parametrize("partitions", [1, 4])
@pytest.mark.parametrize("schedule", ["wave", "fused"])
def test_engine_staggered_bitwise_vs_one_shot(small_world, verifier,
                                              partitions, schedule):
    """The tentpole guarantee: requests admitted mid-flight (while other
    requests are partway through their partition waves) produce results
    bit-identical to the one-shot batch path — per-query theta carries
    plus schedule-invariant row numerics make any join point sound."""
    coll, sim = small_world
    params = _params(verifier, fused=(schedule == "fused"))
    queries = sample_queries(coll, 5, seed=5)
    one_shot = KoiosSearch(coll, sim, params, partitions=partitions)
    ref = one_shot.search_batch(queries, schedule="sequential")

    clock, advance, sleep = _fake_clock()
    eng = RequestEngine(coll, sim, params, partitions=partitions,
                        schedule=schedule, clock=clock, sleep=sleep)
    assert eng.schedule == schedule          # gate really resolved
    for q in queries[:3]:
        eng.submit(q)
    resp = list(eng.step())                  # first cohort starts
    advance(0.25)
    for q in queries[3:]:                    # join mid-flight
        eng.submit(q)
    while eng.pending():
        advance(0.01)
        resp.extend(eng.step())
    resp.sort(key=lambda r: r.rid)

    assert len(resp) == len(queries)
    for r, a in zip(resp, ref):
        assert np.array_equal(r.result.ids, a.ids)
        assert np.array_equal(r.result.lb, a.lb)   # bit-identical floats
        assert np.array_equal(r.result.ub, a.ub)
    # the join really was mid-flight: the late cohort's first wave ran
    # strictly after the early cohort's (it joined later) yet strictly
    # before the early cohort responded (no head-of-line blocking) —
    # so the plan ran more waves than one lock-step pass
    if partitions > 1:
        assert eng.plan.stats.waves > partitions
        early = [t for t in eng.counters.traces if t.rid < 3]
        late = [t for t in eng.counters.traces if t.rid >= 3]
        assert min(t.t_first_wave for t in late) \
            > min(t.t_first_wave for t in early)
        assert min(t.t_first_wave for t in late) \
            < max(t.t_respond for t in early)
    # every request's lifecycle is fully accounted
    s = eng.summary()
    assert s["requests"] == len(queries)
    assert s["steps"] >= 1
    assert s["stream_cache"]["misses"] >= 1


def test_engine_serve_matches_every_one_shot_schedule(small_world):
    """engine == sequential == overlap == fused(one-shot), bitwise."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 6, seed=23)
    one_shot = KoiosSearch(coll, sim, params, partitions=3)
    eng = RequestEngine(coll, sim, params, partitions=3)
    resp = eng.serve(queries)
    for sched in ("sequential", "overlap"):
        for r, a in zip(resp, one_shot.search_batch(queries,
                                                    schedule=sched)):
            assert np.array_equal(r.result.ids, a.ids)
            assert np.array_equal(r.result.lb, a.lb)


def test_stream_cache_hit_parity(small_world):
    """Cached builds are bit-identical to uncached builds — on the miss
    path, the hit path, and duplicate queries within one call."""
    coll, sim = small_world
    queries = sample_queries(coll, 4, seed=3)
    alpha = 0.8
    ref = build_token_stream_batch(queries, sim, alpha)

    cache = TokenStreamCache()
    miss = build_token_stream_batch_cached(queries, sim, alpha, cache)
    hit = build_token_stream_batch_cached(queries, sim, alpha, cache)
    dup = build_token_stream_batch_cached(
        [queries[0], queries[1], queries[0]], sim, alpha, cache)
    for got in (miss, hit):
        for s, r in zip(got, ref):
            assert np.array_equal(s.q_pos, r.q_pos)
            assert np.array_equal(s.token, r.token)
            assert np.array_equal(s.sim, r.sim)    # bit-identical floats
    assert np.array_equal(dup[2].sim, ref[0].sim)
    assert cache.misses == len(queries)
    assert cache.hits == len(queries) + 3          # full rerun + dup call
    assert cache.stats()["hit_rate"] == pytest.approx(
        cache.hits / (cache.hits + cache.misses))

    # a fresh-but-equal query array is the same key (value semantics)
    again = build_token_stream_batch_cached(
        [np.array(queries[0], np.int32)], sim, alpha, cache)
    assert np.array_equal(again[0].sim, ref[0].sim)
    assert cache.misses == len(queries)


def test_stream_cache_eviction_lru(small_world):
    """The byte budget bounds the cache; the LRU entry is evicted first
    and an evicted key rebuilds (miss) to a bit-identical stream."""
    coll, sim = small_world
    q = sample_queries(coll, 3, seed=9)
    alpha = 0.8
    probe = TokenStreamCache()
    streams = build_token_stream_batch_cached(q, sim, alpha, probe)
    sizes = [TokenStreamCache._nbytes(s) for s in streams]
    # budget holds any two of the three streams but never all three
    cache = TokenStreamCache(max_bytes=sum(sizes) - min(sizes) // 2 - 1)
    k0 = cache.key(q[0], alpha, sim)

    build_token_stream_batch_cached([q[0], q[1]], sim, alpha, cache)
    ref0 = build_token_stream_batch(q[:1], sim, alpha)[0]
    assert cache.contains(k0) and len(cache) == 2
    assert cache.bytes == sizes[0] + sizes[1] <= cache.max_bytes

    build_token_stream_batch_cached([q[1]], sim, alpha, cache)  # q0 -> LRU
    build_token_stream_batch_cached([q[2]], sim, alpha, cache)  # evicts q0
    assert cache.evictions == 1
    assert not cache.contains(k0)
    assert len(cache) == 2
    assert cache.bytes == sizes[1] + sizes[2]
    assert cache.describe()["bytes"] == cache.bytes

    misses = cache.misses
    rebuilt = build_token_stream_batch_cached([q[0]], sim, alpha, cache)
    assert cache.misses == misses + 1
    assert np.array_equal(rebuilt[0].sim, ref0.sim)
    assert np.array_equal(rebuilt[0].token, ref0.token)


def test_engine_deadlines_order_admission(small_world):
    """Earliest-deadline-first admission: with room for one request per
    wave, the tighter deadline is served first; deadline outcomes are
    reported per request."""
    coll, sim = small_world
    params = _params()
    q = sample_queries(coll, 2, seed=31)
    clock, advance, sleep = _fake_clock()
    eng = RequestEngine(coll, sim, params, partitions=1,
                        max_wave_requests=1, clock=clock, sleep=sleep)
    eng.submit(q[0], deadline=clock() + 1e9)
    eng.submit(q[1], deadline=clock() + 0.5)
    resp = eng.drain()
    assert [r.rid for r in resp] == [1, 0]
    assert resp[0].deadline_met is not None


def test_serve_batch_reports_true_per_request_latencies(small_world):
    """The serve_batch satellite: per-request admit->respond latencies
    from the engine's instrumentation — not one amortized number —
    plus queue/wave/cache attribution per response."""
    coll, sim = small_world
    params = _params()
    server = SearchServer(coll, sim, params, partitions=2)
    queries = sample_queries(coll, 4, seed=41)
    out = server.serve_batch(queries)
    assert len(out) == len(queries)
    for r in out:
        assert r["latency_s"] >= 0.0
        assert r["queue_s"] >= 0.0
        assert r["waves"] >= 1
        assert "stream_cache_hit" in r
    s = server.engine.summary()
    assert s["requests"] == len(queries)
    assert s["mean_latency_s"] >= 0.0
    # repeated batch: streams now come from the cache
    server.serve_batch(queries)
    assert server.engine.stream_cache.hits >= len(queries)
    # per-query baseline path still serves identical results
    pq = server.serve_batch(queries, batched=False)
    for a, b in zip(out, pq):
        assert a["ids"] == b["ids"]
        assert a["scores"] == b["scores"]


def test_engine_warmup_resets_counters(small_world):
    coll, sim = small_world
    eng = RequestEngine(coll, sim, _params(), partitions=2)
    queries = sample_queries(coll, 2, seed=17)
    eng.warmup(queries)
    assert eng.counters.traces == [] and eng.counters.steps == 0
    assert len(eng.stream_cache) >= 1        # warmup populated the cache
    resp = eng.serve(queries)
    assert all(r.stream_hit for r in resp)   # ... so serving hits it
    assert eng.summary()["requests"] == len(queries)


def test_plan_query_ring_bounded(small_world):
    """Long-lived engines: many admit/respond cycles keep the plan's
    query list bounded (``ExecutionPlan.retire_tiles`` compaction ring,
    DESIGN.md §9 item 9), qi-indexed engine state follows the remap,
    and results stay bit-identical to the one-shot path throughout."""
    coll, sim = small_world
    params = _params()
    clock, advance, sleep = _fake_clock()
    eng = RequestEngine(coll, sim, params, partitions=2,
                        clock=clock, sleep=sleep)
    eng.plan.compact_min = 8             # trigger the ring at test scale
    queries = sample_queries(coll, 6, seed=21)
    one_shot = KoiosSearch(coll, sim, params, partitions=2)
    ref = one_shot.search_batch(queries, schedule="sequential")

    served, max_len = 0, 0
    for cycle in range(12):
        # overlapping submissions: half joins while the other half is
        # mid-flight, so compaction interleaves with live requests
        for q in queries[:3]:
            eng.submit(q)
        resp = list(eng.step())
        for q in queries[3:]:
            eng.submit(q)
        while eng.pending():
            advance(0.01)
            resp.extend(eng.step())
            max_len = max(max_len, len(eng.plan.queries))
        for r in resp:
            a = ref[r.rid % len(queries)]
            assert np.array_equal(r.result.ids, a.ids)
            assert np.array_equal(r.result.lb, a.lb)
        served += len(resp)
    assert served == 12 * len(queries)
    # 72 requests served; without the ring the plan list would hold all
    # of them — with it, the list stays near the live-request ceiling
    assert max_len <= 2 * eng.plan.compact_min, max_len
    assert len(eng.plan.queries) <= eng.plan.compact_min
    assert eng.plan.tiles == []          # everything retired
