"""Per-kernel allclose vs the ref.py oracles (interpret mode on CPU),
with shape/dtype sweeps + hypothesis randomization."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (auction_topk2, auction_topk2_ref, compact_indices,
                           compact_indices_ref, cosine_topk, cosine_topk_ref,
                           ssd, ssd_ref)


def _unit(rng, n, d, dtype=np.float32):
    x = rng.normal(size=(n, d)).astype(dtype)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


# ------------------------------------------------------------- cosine_topk
@pytest.mark.parametrize("nq,nv,d,k,bv", [
    (4, 64, 16, 4, 16),
    (8, 100, 32, 8, 32),      # nv not a multiple of bv (padding path)
    (3, 257, 8, 16, 64),
    (16, 512, 128, 32, 128),
])
def test_cosine_topk_shapes(nq, nv, d, k, bv):
    rng = np.random.default_rng(0)
    qe, ev = _unit(rng, nq, d), _unit(rng, nv, d)
    vals, idx = cosine_topk(qe, ev, k=k, bv=bv)
    rvals, ridx = cosine_topk_ref(jnp.asarray(qe), jnp.asarray(ev), k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals),
                               atol=1e-5, rtol=1e-5)
    # indices must agree where the scores are strictly separated
    sep = np.asarray(rvals)[:, :-1] - np.asarray(rvals)[:, 1:] > 1e-5
    same = np.asarray(idx)[:, :-1] == np.asarray(ridx)[:, :-1]
    assert np.all(same | ~sep)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_cosine_topk_dtypes(dtype):
    rng = np.random.default_rng(1)
    qe, ev = _unit(rng, 4, 16, dtype), _unit(rng, 64, 16, dtype)
    vals, _ = cosine_topk(qe, ev, k=4, bv=16)
    rvals, _ = cosine_topk_ref(jnp.asarray(qe, jnp.float32),
                               jnp.asarray(ev, jnp.float32), 4)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals),
                               atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6), st.integers(2, 40),
       st.integers(1, 6))
def test_cosine_topk_property(seed, nq, nv, k):
    k = min(k, nv)
    rng = np.random.default_rng(seed)
    qe, ev = _unit(rng, nq, 8), _unit(rng, nv, 8)
    vals, _ = cosine_topk(qe, ev, k=k, bv=8)
    rvals, _ = cosine_topk_ref(jnp.asarray(qe), jnp.asarray(ev), k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals),
                               atol=1e-5)


# ------------------------------------------------------------ auction_topk2
@pytest.mark.parametrize("n,m,bn", [(8, 16, 4), (100, 33, 32), (5, 7, 8)])
def test_auction_topk2_shapes(n, m, bn):
    rng = np.random.default_rng(2)
    wm = rng.random((n, m)).astype(np.float32)
    prices = rng.random(m).astype(np.float32)
    w1, w2, j = auction_topk2(wm, prices, bn=bn)
    rw1, rw2, rj = auction_topk2_ref(jnp.asarray(wm), jnp.asarray(prices))
    np.testing.assert_allclose(np.asarray(w1), np.asarray(rw1), atol=1e-6)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(rw2), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(j), np.asarray(rj))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 20), st.integers(2, 20))
def test_auction_topk2_property(seed, n, m):
    rng = np.random.default_rng(seed)
    wm = np.where(rng.random((n, m)) > 0.5, rng.random((n, m)), 0.0)
    wm = wm.astype(np.float32)
    prices = (rng.random(m) * 2).astype(np.float32)
    w1, w2, j = auction_topk2(wm, prices, bn=8)
    rw1, rw2, rj = auction_topk2_ref(jnp.asarray(wm), jnp.asarray(prices))
    np.testing.assert_allclose(np.asarray(w1), np.asarray(rw1), atol=1e-6)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(rw2), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(j), np.asarray(rj))


# --------------------------------------------------------------------- ssd
def _ssd_inputs(rng, Bt, L, H, P, G, S):
    x = rng.normal(size=(Bt, L, H, P)).astype(np.float32)
    dt = np.log1p(np.exp(rng.normal(size=(Bt, L, H)))).astype(np.float32)
    A = (-np.exp(rng.normal(size=H))).astype(np.float32)
    B = rng.normal(size=(Bt, L, G, S)).astype(np.float32) / np.sqrt(S)
    C = rng.normal(size=(Bt, L, G, S)).astype(np.float32) / np.sqrt(S)
    D = rng.normal(size=H).astype(np.float32)
    return x, dt, A, B, C, D


@pytest.mark.parametrize("L,chunk", [(8, 4), (16, 8), (12, 8)])  # 12: pad path
@pytest.mark.parametrize("H,G", [(2, 1), (4, 2)])
def test_ssd_vs_ref(L, chunk, H, G):
    rng = np.random.default_rng(3)
    Bt, P, S = 2, 4, 8
    x, dt, A, B, C, D = _ssd_inputs(rng, Bt, L, H, P, G, S)
    y = ssd(x, dt, A, B, C, D, chunk=chunk)
    yr = np.stack([np.asarray(ssd_ref(jnp.asarray(x[b]), jnp.asarray(dt[b]),
                                      jnp.asarray(A), jnp.asarray(B[b]),
                                      jnp.asarray(C[b]), jnp.asarray(D)))
                   for b in range(Bt)])
    np.testing.assert_allclose(np.asarray(y), yr, atol=2e-4, rtol=2e-4)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3))
def test_ssd_property(seed, Bt):
    rng = np.random.default_rng(seed)
    L, H, P, G, S = 8, 2, 4, 2, 4
    x, dt, A, B, C, D = _ssd_inputs(rng, Bt, L, H, P, G, S)
    y = ssd(x, dt, A, B, C, D, chunk=4)
    yr = np.stack([np.asarray(ssd_ref(jnp.asarray(x[b]), jnp.asarray(dt[b]),
                                      jnp.asarray(A), jnp.asarray(B[b]),
                                      jnp.asarray(C[b]), jnp.asarray(D)))
                   for b in range(Bt)])
    np.testing.assert_allclose(np.asarray(y), yr, atol=2e-4, rtol=2e-4)
    assert not np.any(np.isnan(np.asarray(y)))


# --------------------------------------------------------- flash attention
from repro.kernels import flash_attention, flash_attention_ref  # noqa: E402


@pytest.mark.parametrize("S,bq,bk,causal", [
    (16, 8, 8, True),
    (24, 8, 16, True),
    (20, 8, 8, False),     # padded-KV mask path
    (17, 8, 16, True),     # both paddings
])
def test_flash_attention_vs_ref(S, bq, bk, causal):
    rng = np.random.default_rng(0)
    B, H, d = 2, 2, 8
    q = rng.normal(size=(B, H, S, d)).astype(np.float32)
    k = rng.normal(size=(B, H, S, d)).astype(np.float32)
    v = rng.normal(size=(B, H, S, d)).astype(np.float32)
    out = flash_attention(q, k, v, bq=bq, bk=bk, causal=causal)
    ref_out = flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                  jnp.asarray(v), causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 20), st.booleans())
def test_flash_attention_property(seed, S, causal):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(1, 1, S, 8)).astype(np.float32)
    k = rng.normal(size=(1, 1, S, 8)).astype(np.float32)
    v = rng.normal(size=(1, 1, S, 8)).astype(np.float32)
    out = flash_attention(q, k, v, bq=8, bk=8, causal=causal)
    ref_out = flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                  jnp.asarray(v), causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-5, rtol=1e-5)


# --------------------------------------------------------- compact_indices
@pytest.mark.parametrize("n,p", [(1, 1.0), (1, 0.0), (7, 0.5), (64, 0.25),
                                 (120, 0.9), (255, 0.0)])
def test_compact_indices_vs_ref(n, p):
    rng = np.random.default_rng(n)
    mask = rng.random(n) < p
    idx, cnt = compact_indices(mask)
    ridx, rcnt = compact_indices_ref(jnp.asarray(mask))
    assert np.array_equal(np.asarray(idx), np.asarray(ridx))
    assert int(cnt) == int(rcnt) == int(mask.sum())
    # the contract the wave program relies on: ascending survivor ids,
    # -1 beyond the count — exactly mask.nonzero()[0]
    assert np.array_equal(np.asarray(idx)[:int(cnt)], np.nonzero(mask)[0])
    assert np.all(np.asarray(idx)[int(cnt):] == -1)


def test_compact_indices_vmap_under_jit():
    rng = np.random.default_rng(3)
    masks = rng.random((5, 33)) < 0.4
    f = jax.jit(jax.vmap(compact_indices))
    idx, cnt = f(jnp.asarray(masks))
    for b in range(len(masks)):
        assert np.array_equal(np.asarray(idx)[b, :int(cnt[b])],
                              np.nonzero(masks[b])[0])


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 200))
def test_compact_indices_property(seed, n):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < rng.random()
    idx, cnt = compact_indices(mask)
    assert np.array_equal(np.asarray(idx)[:int(cnt)], np.nonzero(mask)[0])


# ------------------------------------------- auction round kernel (fused-in)
def test_auction_batch_kernel_parity():
    """auction_batch(use_kernel=True) routes every bidding round's profit
    top-2 through the Pallas kernel (the fused-wave TPU path); brackets
    must match the inline jnp pass bit for bit (same tie-breaking)."""
    from repro.core.matching.auction import auction_batch, make_eps_schedule
    rng = np.random.default_rng(0)
    B, N, M = 3, 4, 12
    w = np.where(rng.random((B, N, M)) > 0.5, rng.random((B, N, M)), 0.0)
    w = w.astype(np.float32)
    nq = np.array([4, 3, 2], np.int32)
    nc = np.array([12, 7, 12], np.int32)
    eps = make_eps_schedule(1e-4)
    ref_res = auction_batch(jnp.asarray(w), jnp.asarray(nq),
                            jnp.asarray(nc), eps, jnp.float32(-1e30))
    ker_res = auction_batch(jnp.asarray(w), jnp.asarray(nq),
                            jnp.asarray(nc), eps, jnp.float32(-1e30),
                            use_kernel=True)
    assert np.array_equal(np.asarray(ref_res.lb), np.asarray(ker_res.lb))
    assert np.array_equal(np.asarray(ref_res.ub), np.asarray(ker_res.ub))
    assert np.array_equal(np.asarray(ref_res.assign),
                          np.asarray(ker_res.assign))


# ------------------------------------------------------------ refine_events
def _refine_chunks(seed, n_events, num_sets=24, nq=16, slots_per_set=8,
                   chunk=64):
    from repro.core.token_stream import (EventStream,
                                         pack_events_segmented, pad_events)

    rng = np.random.default_rng(seed)
    set_id = rng.integers(0, num_sets, n_events).astype(np.int32)
    ev = EventStream(
        set_id=set_id,
        q_pos=rng.integers(0, nq, n_events).astype(np.int32),
        # the domain invariant the layout rests on: each flat slot
        # belongs to exactly one set
        slot=(set_id * slots_per_set
              + rng.integers(0, slots_per_set, n_events)).astype(np.int32),
        sim=np.sort(rng.random(n_events).astype(np.float32))[::-1],
        n_tuples=n_events)
    return (pack_events_segmented(*pad_events(ev, chunk)),
            num_sets, num_sets * slots_per_set)


@pytest.mark.parametrize("seed,n_events", [(0, 120), (1, 500), (2, 37)])
def test_refine_events_vs_ref(seed, n_events):
    """The VMEM-resident admission kernel (interpret mode) is bit-equal
    to the packed jnp oracle — the production segmented path — across a
    multi-chunk carry chain."""
    from repro.kernels import refine_events, refine_events_packed_ref

    from repro.core.refinement import refine_carry_init

    (s3, q3, sl3, si3, _snow), num_sets, total_slots = \
        _refine_chunks(seed, n_events)
    state = refine_carry_init(num_sets, 1, total_slots)[:-1]
    for c in range(s3.shape[0]):
        want = refine_events_packed_ref(
            state, jnp.asarray(s3[c]), jnp.asarray(q3[c]),
            jnp.asarray(sl3[c]), jnp.asarray(si3[c]))
        got = refine_events(state, s3[c], q3[c], sl3[c], si3[c])
        for a, b in zip(want, got):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        # thread the carry (alive stays all-true between chunks here)
        state = want[:5] + (state[5],) + want[5:]
    assert bool(np.asarray(state[4]).any())      # something was admitted


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 300))
def test_refine_events_property(seed, n_events):
    from repro.kernels import refine_events, refine_events_packed_ref

    from repro.core.refinement import refine_carry_init

    (s3, q3, sl3, si3, _snow), num_sets, total_slots = \
        _refine_chunks(seed, n_events, num_sets=9, nq=40, chunk=128)
    rng = np.random.default_rng(seed + 1)
    alive = jnp.asarray(rng.random(num_sets) > 0.3)
    st0 = refine_carry_init(num_sets, 2, total_slots)
    state = st0[:5] + (alive,) + st0[6:-1]
    want = refine_events_packed_ref(
        state, jnp.asarray(s3[0]), jnp.asarray(q3[0]),
        jnp.asarray(sl3[0]), jnp.asarray(si3[0]))
    got = refine_events(state, s3[0], q3[0], sl3[0], si3[0])
    for a, b in zip(want, got):
        assert np.array_equal(np.asarray(a), np.asarray(b))
