"""Logical-axis -> mesh-axis sharding rules (GSPMD/pjit).

Mesh axes: ``("data", "model")`` per pod, ``("pod", "data", "model")``
multi-pod (launch/mesh.py).  FSDP axes = ("pod", "data") when present.

Parameter rules (train & serve — serve reuses the FSDP layout and
all-gathers weights per layer; the EP-heavy serving alternative is a §Perf
experiment):

  embeddings / lm head     (V, d)        -> (model, fsdp)
  attn q/k/v projections   (d, H*hd)     -> (fsdp, model)   column parallel
  attn output projection   (H*hd, d)     -> (model, fsdp)   row parallel
  MLA down-projections     (d, r)        -> (fsdp, None)
  MLA up-projections       (r, H*x)      -> (None, model)
  mlp gate/up              (d, ff)       -> (fsdp, model)
  mlp down                 (ff, d)       -> (model, fsdp)
  MoE expert stacks        (E, d, ff)    -> (model, fsdp, None)   EP
                           (E, ff, d)    -> (model, None, fsdp)
  MoE router               (d, E)        -> (fsdp, None)
  mamba in_proj            (d, 2i+2GS+H) -> (fsdp, model)
  mamba out_proj           (i, d)        -> (model, fsdp)
  mamba conv/gate/A/dt/D   channel dim   -> (model)
  norms                    (d,)          -> replicated

Stacked (scanned) parameters carry 1-2 leading layer dims -> padded with
None.  Activations/batch: batch dim over (pod, data); KV caches: batch over
(pod, data), heads over model; ssm state heads over model."""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(axis_names: Sequence[str]):
    ax = tuple(a for a in ("pod", "data") if a in axis_names)
    if len(ax) == 1:
        return ax[0]
    return ax if ax else None


def dp_axes(axis_names: Sequence[str]):
    return fsdp_axes(axis_names)


_RULES = [
    # (path substrings (all must match), trailing spec builder)
    (("embed", "table"), lambda f: ("model", f)),
    (("out", "table"), lambda f: ("model", f)),
    (("wq_down",), lambda f: (f, None)),
    (("wkv_down",), lambda f: (f, None)),
    (("wq_up",), lambda f: (None, "model")),
    (("wkv_up",), lambda f: (None, "model")),
    (("attn", "wq"), lambda f: (f, "model")),
    (("attn", "wk"), lambda f: (f, "model")),
    (("attn", "wv"), lambda f: (f, "model")),
    (("attn", "wo"), lambda f: ("model", f)),
    (("moe", "shared", "w_gate"), lambda f: (f, "model")),
    (("moe", "shared", "w_up"), lambda f: (f, "model")),
    (("moe", "shared", "w_down"), lambda f: ("model", f)),
    (("moe", "router"), lambda f: (f, None)),
    (("moe", "w_gate"), lambda f: ("model", f, None)),
    (("moe", "w_up"), lambda f: ("model", f, None)),
    (("moe", "w_down"), lambda f: ("model", None, f)),
    (("w_gate",), lambda f: (f, "model")),
    (("w_up",), lambda f: (f, "model")),
    (("w_down",), lambda f: ("model", f)),
    (("in_proj",), lambda f: (f, "model")),
    (("out_proj",), lambda f: ("model", f)),
    (("conv_w",), lambda f: (None, "model")),
    (("conv_b",), lambda f: ("model",)),
    (("gate_norm",), lambda f: ("model",)),
    (("mixer", "A_log"), lambda f: ("model",)),
    (("mixer", "dt_bias"), lambda f: ("model",)),
    (("mixer", "D"), lambda f: ("model",)),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _axis_size(axes, sizes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return sizes.get(axes, 1)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def _divisibility_guard(spec, shape, sizes):
    """GSPMD requires every sharded dim to divide evenly by its axis
    product; drop (replicate) the axes of any dim that does not (odd
    vocabularies, small head counts — see EXPERIMENTS.md §Dry-run notes)."""
    fixed = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                         - len(tuple(spec)))):
        n = _axis_size(axes, sizes)
        fixed.append(axes if (n > 0 and dim % n == 0) else None)
    return P(*fixed)


_HEAD_DIM_RULES = {
    # attn weights whose model-sharded dim is a (heads*hd) dim: position of
    # that dim in the trailing spec (-1 = last/out, -2 = first/in)
    ("attn", "wq"): -1, ("attn", "wk"): -1, ("attn", "wv"): -1,
    ("attn", "wo"): -2,
}


def _head_granularity_guard(spec, shape, sizes, head_dim, pos):
    """Sharding a (heads*hd) dim must land on whole heads: if
    (dim/hd) % model != 0, GSPMD would split inside heads and reshard the
    (B,S,H,hd) activations every layer (§Perf finding, EXPERIMENTS.md
    tinyllama iteration 3).  Replicate that dim instead."""
    if head_dim is None:
        return spec
    inner = list(spec)
    idx = len(shape) + pos if pos < 0 else pos
    axes = inner[idx]
    n = _axis_size(axes, sizes)
    heads = shape[idx] // max(head_dim, 1)
    if n > 1 and (shape[idx] % head_dim or heads % n):
        inner[idx] = None
    return P(*inner)


def _leaf_pspec(path, leaf, axis_names, sizes, head_dim=None) -> P:
    ps = _path_str(path)
    f = fsdp_axes(axis_names)
    ndim = len(leaf.shape)
    for keys, rule in _RULES:
        if all(k in ps for k in keys):
            trailing = rule(f)
            if len(trailing) > ndim:     # tiny smoke tensors
                trailing = trailing[-ndim:]
            pad = (None,) * (ndim - len(trailing))
            spec = _divisibility_guard(P(*(pad + tuple(trailing))),
                                       leaf.shape, sizes)
            for hkeys, pos in _HEAD_DIM_RULES.items():
                if all(k in ps for k in hkeys) and "wq_" not in ps \
                        and "wkv_" not in ps:
                    spec = _head_granularity_guard(spec, leaf.shape, sizes,
                                                   head_dim, pos)
                    break
            return spec
    return P()                            # replicate (norms, scalars)


def param_pspecs(spec_tree: Any, axis_names: Sequence[str],
                 axis_sizes: dict | None = None, head_dim: int | None = None):
    """PartitionSpec tree congruent with a params (or ShapeDtypeStruct)
    tree.  ``axis_sizes`` ({axis: size}) enables the divisibility guard;
    ``head_dim`` the head-granularity guard for attention weights."""
    sizes = axis_sizes or {}
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_pspec(path, leaf, axis_names, sizes,
                                       head_dim),
        spec_tree)


def opt_state_pspecs(opt_specs: Any, p_pspecs: Any):
    """Optimizer state: moments inherit the parameter spec; count
    replicated."""
    mu = jax.tree_util.tree_map(
        lambda spec: {"m": spec, "v": spec}, p_pspecs,
        is_leaf=lambda x: isinstance(x, P))
    return {"mu": mu, "count": P()}


_BATCH_RULES = {
    "tokens": lambda d: P(d, None),
    "labels": lambda d: P(d, None),
    "token": lambda d: P(d, None),
    "frames": lambda d: P(d, None, None),
    "prefix": lambda d: P(d, None, None),
    "cache_index": lambda d: P(),
}

_CACHE_RULES = {
    # leading layer-stack dims padded by _pad below
    "k": lambda d: P(d, None, "model", None),
    "v": lambda d: P(d, None, "model", None),
    "ckv": lambda d: P(d, None, None),
    "k_rope": lambda d: P(d, None, None),
    "ssm": lambda d: P(d, "model", None, None),
    "cx": lambda d: P(d, None, "model"),
    "cb": lambda d: P(d, None, "model"),
    "cc": lambda d: P(d, None, "model"),
    "memory": lambda d: P(d, None, None),
}


def _pad(spec: P, ndim: int) -> P:
    inner = tuple(spec)
    if len(inner) > ndim:
        inner = inner[-ndim:]
    return P(*(((None,) * (ndim - len(inner))) + inner))


def input_pspecs(input_specs: Any, axis_names: Sequence[str],
                 axis_sizes: dict | None = None):
    """PartitionSpecs for a step's input tree (train batch or decode
    state)."""
    d = dp_axes(axis_names)
    sizes = axis_sizes or {}

    def leaf(path, x):
        ps = _path_str(path)
        name = ps.split("/")[-1]
        ndim = len(x.shape)
        spec = None
        if "caches" in ps or name in _CACHE_RULES:
            rule = _CACHE_RULES.get(name)
            if rule is not None:
                spec = _pad(rule(d), ndim)
        if spec is None and name in _BATCH_RULES:
            spec = _pad(_BATCH_RULES[name](d), ndim)
        if spec is None:
            return P()
        return _divisibility_guard(spec, x.shape, sizes)

    return jax.tree_util.tree_map_with_path(leaf, input_specs)


def guard_pspec(spec: P, shape, mesh: Mesh) -> P:
    """Public divisibility guard for hand-built specs (e.g. logits)."""
    return _divisibility_guard(_pad(spec, len(shape)), shape,
                               dict(mesh.shape))


def to_shardings(pspec_tree: Any, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------- bound exchange
def _round_down_f32(values):
    """float32 narrowing that never rounds UP: theta_lb is a certified
    lower bound, and nearest-rounding a float64 bound up by half an ulp
    would let the exchange prune a boundary candidate unsoundly.  One ulp
    of looseness only ever keeps an extra candidate alive."""
    v64 = np.asarray(values, np.float64)
    v32 = v64.astype(np.float32)
    return np.where(v32.astype(np.float64) > v64,
                    np.nextafter(v32, np.float32(-np.inf)), v32)


@functools.lru_cache(maxsize=None)
def _amax_fn(mesh: Mesh, present: tuple):
    from jax.experimental.shard_map import shard_map

    @functools.partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P())
    def _amax(v):
        for a in present:
            v = jax.lax.pmax(v, a)
        return v

    return _amax


def all_reduce_max_traced(values, mesh: Optional[Mesh],
                          axes: Sequence[str] = ("pod", "data")):
    """In-trace theta_lb exchange for the fused wave program (DESIGN.md §3).

    The same all-reduce-max as :func:`all_reduce_max`, but callable from
    *inside* a jit trace (shard_map composes under jit), so the wave
    program exchanges bounds on-device between verification rounds with
    no host round-trip.  ``values`` stays float32 throughout — there is
    no float64 narrowing to guard, so no round-down is needed.  With no
    mesh (or none of the axes present) it is the identity, which keeps
    the single-process CPU path mesh-free."""
    if mesh is None:
        return values
    present = tuple(a for a in axes if a in mesh.axis_names)
    if not present:
        return values
    return _amax_fn(mesh, present)(values)


def all_reduce_max(values, mesh: Mesh, axes: Sequence[str] = ("pod", "data")):
    """All-reduce-max of a replicated bound vector over the repository
    shard axes (DESIGN.md §5).

    The partition scheduler's theta_lb exchange: every shard contributes
    its per-query lower bounds and receives the global max, so a bound
    raised anywhere prunes candidates everywhere.  ``values`` is a (B,)
    array (one slot per in-flight query), replicated across the mesh; axes
    absent from the mesh are skipped, so the same call works on the
    production (pod, data, model) mesh, the single-pod (data, model) mesh,
    and the single-device smoke mesh.  The shard_map trace is cached per
    (mesh, axes) — this runs once per verification round.  Returns a host
    ndarray (float32, rounded toward -inf so the bound stays certified).
    """
    vals = _round_down_f32(values)
    present = tuple(a for a in axes if a in mesh.axis_names)
    if not present:
        return vals
    return np.asarray(_amax_fn(mesh, present)(jax.numpy.asarray(vals)))


def bound_exchange_for(mesh: Mesh, axes: Sequence[str] = ("pod", "data")):
    """A scheduler ``bound_exchange`` hook closing over ``mesh``."""
    return lambda theta: all_reduce_max(theta, mesh, axes)
