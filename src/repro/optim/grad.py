"""Gradient utilities: global-norm clipping, accumulation, and int8
error-feedback compression for the cross-pod all-reduce (DESIGN.md §5).

Compression model: the slow link at multi-pod scale is the inter-pod DCN/ICI
hop of the data-parallel gradient all-reduce.  We quantize each leaf to int8
with a per-leaf scale before the ``pod``-axis reduction and keep the
quantization residual locally (error feedback), which preserves convergence
(Karimireddy et al. 2019).  The 'pod' all-reduce then moves 1/4 of the bf16
bytes.  ``compress/decompress`` are exposed separately so the launcher can
wrap only the pod-axis psum."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-6))
    return jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), norm


def accumulate(loss_fn, params, batches):
    """Gradient accumulation over the leading microbatch axis via scan."""
    def body(acc, micro):
        loss, g = jax.value_and_grad(loss_fn)(params, micro)
        acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), acc, g)
        return acc, loss

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    grads, losses = jax.lax.scan(body, zeros, batches)
    n = losses.shape[0]
    return (jax.tree_util.tree_map(lambda g: g / n, grads),
            jnp.mean(losses))


# --------------------------------------------------- int8 error feedback

def compress(tree, residual):
    """tree + residual -> (int8 tree, scales, new residual)."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_r = g - q.astype(jnp.float32) * scale
        return q, scale, new_r

    flat, treedef = jax.tree_util.tree_flatten(tree)
    r_flat = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat, r_flat)]
    q = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    scales = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_r = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return q, scales, new_r


def decompress(q, scales):
    return jax.tree_util.tree_map(
        lambda qq, s: qq.astype(jnp.float32) * s, q, scales)


def zero_residual(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
