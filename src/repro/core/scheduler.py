"""Partition-scheduled KOIOS execution engine (paper §VI scale-out).

Every search request — single query, request batch, partitioned repository,
or all three — is one :class:`ExecutionPlan`: a set of (query x partition)
*tiles* driven through one shared pipeline.  The scheduler replaces the
historical trio of hand-rolled loops (per-query search, per-partition host
loop, per-partition batched search) with a single code path:

  overlap (default)
      All tiles' refinement scans are dispatched before any is
      materialized (JAX dispatch is async: partition p+1's scan executes
      on-device while the host expands events for and materializes
      earlier tiles, with no host round-trip between partitions — the
      sequential loop instead stalls every partition's refinement behind
      the previous partition's full post-processing), every tile's
      verification requests drain through ONE cross-partition/cross-query
      :class:`VerifierPool` queue (fewer, fuller solver calls), and
      theta_lb feedback is *bidirectional*: a bound raised by any tile's
      verification round immediately re-prunes still-queued candidates of
      every other tile of the same query — including tiles of *earlier*
      partitions, which the sequential running-max loop could never reach.
      On a device mesh the per-round bound exchange is an all-reduce-max
      over the (pod, data) axes (``bound_exchange`` hook; see
      ``repro.runtime.sharding.all_reduce_max`` and DESIGN.md §5).

  sequential
      The pre-scheduler reference trajectory: partitions run one after the
      other, later partitions inheriting the running max of earlier
      partitions' final k-th scores.  Kept (cheaply — it is the same tile
      machinery with a different drive order) as the bit-identical
      baseline for tests and the A/B arm of
      ``benchmarks/response_time.py --partitions N --overlap``.

Both schedules return exact top-k results; tests assert they are
bit-identical on every (partitions x batch x verifier) combination.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from .postprocess import PostprocessState, VerifierPool, drive_states
from .refinement import _dispatch_refinement, _materialize_refinement
from .token_stream import build_token_stream_batch, expand_to_events
from .types import (SearchParams, SearchResult, SearchStats, SetCollection)
from ..runtime import instrument


def _build_streams(plan: "ExecutionPlan", sim, params: SearchParams,
                   streams) -> list:
    """Plan-wide per-query streams: the precomputed list when the caller
    (request engine / stream-cache-aware search) supplies one, else one
    stacked batch build — construction is split from execution so streams
    can come from the LRU cache (DESIGN.md §3.2)."""
    if streams is not None:
        assert len(streams) == len(plan.queries)
        return streams
    return build_token_stream_batch(plan.queries, sim, params.alpha,
                                    use_kernel=params.stream_use_kernel)


@dataclasses.dataclass
class SchedulerStats:
    """Instrumentation of one plan execution (the overlap/fused story)."""

    tiles: int = 0                 # (query x partition) tiles executed
    rounds: int = 0                # host lock-step verification rounds
    fused_requests: int = 0        # verify requests fused across tiles
    bound_raises: int = 0          # tile thetas raised by another tile
    backward_raises: int = 0       # ... where the source is a LATER partition
    schedule: str = ""             # resolved drive order of this plan
    waves: int = 0                 # waves executed (fused device programs
    #                                or the engine's host wave steps)
    device_rounds: int = 0         # verification rounds run inside waves
    theta_trace: List[np.ndarray] = dataclasses.field(default_factory=list)
    # per-query theta_lb after each round (monotone non-decreasing rows)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["theta_trace"] = [t.tolist() for t in self.theta_trace]
        return d


@dataclasses.dataclass
class _Tile:
    """One (query, partition) unit of work."""

    qi: int                        # query index within the plan
    pi: int                        # partition index within the plan
    index: "object"                # KoiosIndex of the partition
    id_base: int                   # added to candidate ids in pool requests
    events: Optional[object] = None
    launched: Optional[tuple] = None      # async refinement handle
    ref: Optional[object] = None
    state: Optional[PostprocessState] = None
    result: Optional[SearchResult] = None


def _empty_result() -> SearchResult:
    return SearchResult(
        ids=np.zeros(0, np.int32), lb=np.zeros(0, np.float32),
        ub=np.zeros(0, np.float32), stats=SearchStats())


class ExecutionPlan:
    """A request batch decomposed into (query x partition) tiles.

    ``pool_coll`` is the collection the shared verifier resolves candidate
    ids against; ``request_id_bases[pi]`` translates partition-local ids
    into that collection's id space (the partition's global offset when
    ``pool_coll`` is the full repository, 0 when it is the partition
    itself).
    """

    # Plan-query ring bounds (DESIGN.md §9 item 9): once more than
    # ``compact_threshold`` of a plan's query slots are retired
    # tombstones (and the list is at least ``compact_min`` long),
    # ``retire_tiles`` compacts the append-only list in place — a
    # weeks-long engine's plan stays proportional to its LIVE requests
    # instead of growing with every request ever served.
    compact_threshold: float = 0.5
    compact_min: int = 64

    def __init__(self, indexes: Sequence, queries: Sequence[np.ndarray],
                 pool_coll: SetCollection,
                 theta0: Optional[Sequence[float]] = None,
                 request_id_bases: Optional[Sequence[int]] = None,
                 epoch: int = 0):
        # a ShardedCollection resource is a valid tile source: its shards
        # ARE the plan's per-partition indexes (borrowed, never copied)
        if hasattr(indexes, "shards"):
            indexes = indexes.shards
        # audit tag (DESIGN.md §6.5): the collection epoch this plan's
        # tiles compute against — a plan NEVER migrates epochs; engines
        # rebuild the plan on resync
        self.epoch = int(epoch)
        self.indexes = list(indexes)
        self.queries = [np.asarray(q, dtype=np.int32) for q in queries]
        self.pool_coll = pool_coll
        self.theta0 = np.asarray(
            theta0 if theta0 is not None else [0.0] * len(self.queries),
            np.float64)
        bases = (request_id_bases if request_id_bases is not None
                 else [ix.id_offset for ix in self.indexes])
        self._bases = [int(b) for b in bases]
        self.tiles = [
            _Tile(qi=qi, pi=pi, index=index, id_base=self._bases[pi])
            for pi, index in enumerate(self.indexes)
            for qi in range(len(self.queries))]
        self.stats = SchedulerStats(tiles=len(self.tiles))

    # ------------------------------------------------------------- helpers
    def add_queries(self, queries: Sequence[np.ndarray],
                    theta0: Optional[Sequence[float]] = None
                    ) -> "tuple[range, List[_Tile]]":
        """Absorb late-arriving queries into the plan (continuous
        batching, DESIGN.md §3.2): appends the queries plus one tile per
        partition each, and returns their query-index range and the new
        tiles.  Sound mid-flight: a query's tiles only ever read its own
        theta entry, and row-level numerics are schedule-invariant, so
        joining between waves cannot perturb any in-flight query."""
        queries = [np.asarray(q, dtype=np.int32) for q in queries]
        lo = len(self.queries)
        self.queries.extend(queries)
        extra = np.asarray(
            theta0 if theta0 is not None else [0.0] * len(queries),
            np.float64)
        assert len(extra) == len(queries)
        self.theta0 = np.concatenate([self.theta0, extra])
        new = [_Tile(qi=qi, pi=pi, index=index, id_base=self._bases[pi])
               for pi, index in enumerate(self.indexes)
               for qi in range(lo, len(self.queries))]
        self.tiles.extend(new)
        self.stats.tiles = len(self.tiles)
        return range(lo, len(self.queries)), new

    def retire_tiles(self, qis) -> "Optional[dict]":
        """Drop responded queries' tiles (and query arrays) so a
        long-running engine plan does not accumulate finished work;
        their queries-list slots are tombstoned, and once tombstones
        exceed ``compact_threshold`` of a ``compact_min``-sized list the
        list is compacted in place (the bounded ring, DESIGN.md §9 item
        9).  Returns the {old_qi: new_qi} remap when a compaction
        happened (callers holding qi-indexed state — the request engine
        — must apply it), else None."""
        gone = set(int(qi) for qi in qis)
        self.tiles = [t for t in self.tiles if t.qi not in gone]
        for qi in gone:
            self.queries[qi] = None
        retired = sum(1 for q in self.queries if q is None)
        if (len(self.queries) < self.compact_min
                or retired <= self.compact_threshold * len(self.queries)):
            return None
        live = [qi for qi, q in enumerate(self.queries) if q is not None]
        remap = {old: new for new, old in enumerate(live)}
        self.queries = [self.queries[old] for old in live]
        self.theta0 = self.theta0[live]
        for t in self.tiles:
            t.qi = remap[t.qi]
        return remap

    def results(self) -> List[List[SearchResult]]:
        """Per-query, per-partition (partition-ascending) local results."""
        out: List[List[SearchResult]] = [[] for _ in self.queries]
        for t in sorted(self.tiles, key=lambda t: (t.qi, t.pi)):
            out[t.qi].append(t.result)
        return out


def _launch_tile(tile: _Tile, stream, query, params: SearchParams) -> None:
    """Expand the (partition-independent) stream through the tile's
    inverted index and dispatch its refinement scan asynchronously."""
    coll = tile.index.coll
    events = expand_to_events(stream, tile.index.inv)
    if len(events) == 0:
        tile.result = _empty_result()
        return
    tile.events = events
    tile.launched = _dispatch_refinement(
        events, coll.set_sizes, len(query), coll.total_tokens,
        params.k, params.alpha, params.chunk_size, params.ub_mode,
        layout=params.refine_layout)


def _materialize_tile(tile: _Tile) -> None:
    out, n_chunks = tile.launched
    tile.launched = None
    tile.ref = _materialize_refinement(out, n_chunks, tile.events)
    tile.events = None          # free the expanded postings (P x B tiles)


def _make_state(tile: _Tile, query, theta0: float,
                params: SearchParams) -> None:
    ref = tile.ref
    ref.theta_lb = max(ref.theta_lb, float(theta0))
    surv = (ref.seen & ref.alive).nonzero()[0]
    tile.state = PostprocessState(
        query, surv, ref.S[surv], ref.ub[surv], ref.theta_lb, params,
        ref.stats, id_base=tile.id_base)
    tile.ref = None             # survivors are copied into the state


def _finish_tile(tile: _Tile, id_offset: int) -> None:
    r = tile.state.result()
    tile.result = SearchResult(
        ids=(r.ids + id_offset).astype(np.int32),
        lb=r.lb, ub=r.ub, stats=r.stats)


def run_plan(plan: ExecutionPlan, sim_provider, params: SearchParams,
             schedule: str = "overlap",
             bound_exchange: Optional[Callable] = None,
             mesh=None, streams=None) -> List[List[SearchResult]]:
    """Drive every tile of ``plan`` to completion; returns per-query lists
    of per-partition results (partition order), ids already globalized.

    ``schedule='fused'`` resolves to the on-device wave pipeline where it
    can run (TPU backend, or interpret mode when ``params.fused ==
    'interpret'``, with a dense cosine provider — see
    ``core.wave.fused_available``) and falls back to ``overlap``
    elsewhere; all three schedules return bit-identical exact results.
    ``mesh`` plugs the repository-shard mesh into the fused program's
    on-device bound exchange (DESIGN.md §5).  ``streams`` optionally
    supplies precomputed per-query token streams (the stream-cache path,
    DESIGN.md §3.2) instead of building them here."""
    if schedule == "fused":
        from .wave import fused_available
        if not fused_available(params, sim_provider):
            schedule = "overlap"
    plan.stats.schedule = schedule
    if schedule == "fused":
        _run_fused(plan, sim_provider, params, bound_exchange, mesh,
                   streams=streams)
    elif schedule == "overlap":
        _run_overlapped(plan, sim_provider, params, bound_exchange,
                        streams=streams)
    elif schedule == "sequential":
        _run_sequential(plan, sim_provider, params, bound_exchange,
                        streams=streams)
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    return plan.results()


# --------------------------------------------------------------- wave step
def run_wave(plan: ExecutionPlan, tiles: Sequence[_Tile], streams,
             theta, pool: VerifierPool, params: SearchParams) -> None:
    """Execute one host *wave* — any subset of the plan's tiles, mixing
    queries AND partitions — to completion, then fold each finished
    tile's k-th score back into its query's ``theta`` carry (in place).

    This is plan execution split from plan construction: the request
    engine (``runtime.engine``) calls it with whatever tile cohort the
    admission queue coalesced for this step (a tile per live request,
    each at its own next partition — continuous batching), while
    ``_run_sequential`` drives one partition's tiles per wave.  Within
    the wave, refinement dispatch is pipelined across all tiles and
    verification drains through the shared ``pool`` queue — the overlap
    machinery at wave granularity.
    """
    plan.stats.waves += 1
    for t in tiles:
        _launch_tile(t, streams[t.qi], plan.queries[t.qi], params)
    live = [t for t in tiles if t.result is None]
    for t in live:
        _materialize_tile(t)
        _make_state(t, plan.queries[t.qi], theta[t.qi], params)
    drive_states(pool, [t.state for t in live],
                 round_hook=lambda n: _count_round(plan, n))
    for t in live:
        _finish_tile(t, t.index.id_offset)
    for t in tiles:
        if len(t.result.lb) >= params.k:
            theta[t.qi] = max(theta[t.qi],
                              float(t.result.lb[params.k - 1]))


# --------------------------------------------------------------- sequential
def _run_sequential(plan: ExecutionPlan, sim, params: SearchParams,
                    bound_exchange: Optional[Callable] = None,
                    streams=None) -> None:
    """Partitions one after the other, sharing the running max of final
    k-th scores — the paper's host reference loop (and the historical
    ``search``/``search_batch`` trajectory, bit for bit): one
    :func:`run_wave` per partition.  The bound exchange (when
    configured) runs once per completed partition, at the loop's single
    inter-partition communication point."""
    streams = _build_streams(plan, sim, params, streams)
    pool = VerifierPool(plan.pool_coll, sim, params)
    theta = plan.theta0.copy()
    for pi in range(len(plan.indexes)):
        run_wave(plan, [t for t in plan.tiles if t.pi == pi], streams,
                 theta, pool, params)
        if pi < len(plan.indexes) - 1:      # no consumer after the last
            theta = _exchange(theta, bound_exchange)


# ------------------------------------------------------------------ overlap
def _run_overlapped(plan: ExecutionPlan, sim, params: SearchParams,
                    bound_exchange: Optional[Callable],
                    streams=None) -> None:
    """All tiles in flight at once: pipelined refinement dispatch across
    partitions, one global verification queue, bidirectional bounds."""
    streams = _build_streams(plan, sim, params, streams)
    # Dispatch EVERY tile's refinement before materializing any: the
    # device works through later partitions' scans back-to-back while the
    # host expands and materializes earlier tiles (the sequential loop
    # instead parks each partition's refinement behind the previous
    # partition's full post-processing).
    for t in plan.tiles:
        _launch_tile(t, streams[t.qi], plan.queries[t.qi], params)
    live = [t for t in plan.tiles if t.result is None]
    for t in live:
        _materialize_tile(t)

    # Initial bound exchange: every tile starts from the best refinement
    # bound of ANY of its query's tiles (each partition's k-th greedy score
    # lower-bounds the global k-th SO), not just its own.
    theta = plan.theta0.copy()
    _exchange_bounds(plan, live, theta, bound_exchange,
                     tile_theta=lambda t: t.ref.theta_lb,
                     raisable=lambda t: True)
    for t in live:
        _make_state(t, plan.queries[t.qi], theta[t.qi], params)

    pool = VerifierPool(plan.pool_coll, sim, params)
    drive_states(pool, [t.state for t in live],
                 round_hook=lambda n: _feedback_round(plan, live, theta,
                                                      bound_exchange, n))
    for t in live:
        _finish_tile(t, t.index.id_offset)


# --------------------------------------------------------------------- fused
def _wave_tile_state(tile: _Tile, row: int, launch, out, query,
                     theta_q: float, params: SearchParams) -> bool:
    """Resume one tile from a materialized wave's row: build its
    ``PostprocessState`` via ``PostprocessState.from_wave`` (or mark the
    tile empty).  Returns whether the tile is live.  Shared by the
    all-partitions fused drive and the engine's single-wave step."""
    meta = launch.tile_meta[row]
    if meta.empty:
        tile.result = _empty_result()
        return False
    surv = out.surv_idx[row][:int(out.surv_cnt[row])]
    stats = SearchStats(
        candidates=int(out.candidates[row]),
        pruned_refinement=int(out.pruned_ref[row]),
        pruned_postprocess=int(out.pruned_post[row]),
        stream_tuples=meta.n_tuples,
        stream_events=meta.n_events,
        refinement_chunks=meta.n_chunks)
    tile.state = PostprocessState.from_wave(
        query, surv,
        out.lb[row][surv], out.ub[row][surv],
        out.live[row][surv], out.verified[row][surv],
        em_early=int(out.em_early[row]),
        em_full=int(out.em_full[row]),
        theta_lb=float(theta_q), params=params, stats=stats,
        id_base=tile.id_base)
    return True


def run_fused_wave(plan: ExecutionPlan, tiles: Sequence[_Tile], streams,
                   theta, pool: VerifierPool, params: SearchParams,
                   runner) -> None:
    """Execute one fused *device* wave for a tile cohort sharing a single
    partition (the engine's continuous-batching step, device edition):
    dispatch the wave program over the cohort's queries, resume each tile
    through ``PostprocessState.from_wave``, drain the host continuation
    through the shared ``pool``, and fold finished k-th scores back into
    the per-query ``theta`` carries.  ``runner`` is an engine-lifetime
    :class:`core.wave.WaveRunner` (see ``wave.wave_runner_for``), so the
    normalized table and per-partition dense operands are reused across
    requests."""
    from .wave import _pow2

    assert len({t.pi for t in tiles}) == 1, "one partition per fused wave"
    index = tiles[0].index
    queries = [plan.queries[t.qi] for t in tiles]
    wave_streams = [streams[t.qi] for t in tiles]
    theta0 = np.asarray([theta[t.qi] for t in tiles], np.float64)
    theta_dev = runner.init_theta(theta0, _pow2(max(1, len(queries))))
    launch, theta_dev = runner.launch_wave(index, queries, wave_streams,
                                           theta_dev)
    plan.stats.waves += 1
    plan.stats.device_rounds += launch.cfg.rounds
    out = runner.materialize(launch)
    instrument.record("d2h:theta_materialize")
    theta_out = np.maximum(theta0, np.asarray(theta_dev,
                                              np.float64)[:len(queries)])
    live = []
    for row, t in enumerate(tiles):
        # theta carries fold the on-device exchange back in (monotone)
        theta[t.qi] = max(theta[t.qi], float(theta_out[row]))
        if _wave_tile_state(t, row, launch, out, plan.queries[t.qi],
                            theta_out[row], params):
            live.append(t)
    drive_states(pool, [t.state for t in live],
                 round_hook=lambda n: _count_round(plan, n))
    for t in live:
        _finish_tile(t, t.index.id_offset)
    for t in tiles:
        if len(t.result.lb) >= params.k:
            theta[t.qi] = max(theta[t.qi],
                              float(t.result.lb[params.k - 1]))


def _run_fused(plan: ExecutionPlan, sim, params: SearchParams,
               bound_exchange: Optional[Callable], mesh=None,
               streams=None) -> None:
    """On-device wave pipeline (DESIGN.md §3): one device program per
    partition wave — refinement chunk scans, candidate compaction,
    theta_lb exchange, and the first R verification rounds — with waves
    chained through a donated on-device theta carry (no host round-trip
    between partitions).  The host drive loop resumes from each tile's
    wave state for the remaining verification, with the same global queue
    and bidirectional bound feedback as the overlap schedule."""
    from .wave import _pow2, wave_runner_for

    streams = _build_streams(plan, sim, params, streams)
    runner = wave_runner_for(sim, params, mesh=mesh)
    B_pad = _pow2(max(1, len(plan.queries)))
    theta_dev = runner.init_theta(plan.theta0, B_pad)
    # ONE host->device payload for the whole plan: the compact stream
    # tuples (partition-independent) — each wave expands them in-trace
    # through its partition's device-resident index (DESIGN.md §3.3)
    stream_ops = runner.stream_operands(plan.queries, streams, B_pad)

    # Dispatch EVERY wave before materializing any (the overlap idea, one
    # level up): wave p+1's program queues behind wave p on-device while
    # the host sizes and dispatches later partitions' waves.
    launches = []
    for index in plan.indexes:
        launch, theta_dev = runner.launch_wave(index, plan.queries,
                                               streams, theta_dev,
                                               stream_ops=stream_ops)
        launches.append(launch)
        plan.stats.waves += 1
        plan.stats.device_rounds += launch.cfg.rounds

    instrument.record("d2h:theta_materialize")
    theta = np.maximum(plan.theta0,
                       np.asarray(theta_dev,
                                  np.float64)[:len(plan.queries)])
    plan.stats.theta_trace.append(theta.copy())

    live: List[_Tile] = []
    for pi, launch in enumerate(launches):
        out = runner.materialize(launch)
        for t in (t for t in plan.tiles if t.pi == pi):
            if _wave_tile_state(t, t.qi, launch, out,
                                plan.queries[t.qi], theta[t.qi], params):
                live.append(t)

    # host continuation: same exchange + global queue as overlap
    _exchange_bounds(plan, live, theta, bound_exchange,
                     tile_theta=lambda t: t.state.theta_lb,
                     raisable=lambda t: not t.state.finished())
    for t in live:
        if not t.state.finished():
            t.state.raise_theta(theta[t.qi])
    pool = VerifierPool(plan.pool_coll, sim, params)
    drive_states(pool, [t.state for t in live],
                 round_hook=lambda n: _feedback_round(plan, live, theta,
                                                      bound_exchange, n))
    for t in live:
        _finish_tile(t, t.index.id_offset)


def _count_round(plan: ExecutionPlan, n_active: int) -> None:
    plan.stats.rounds += 1
    plan.stats.fused_requests += n_active


def _feedback_round(plan: ExecutionPlan, tiles, theta: np.ndarray,
                    bound_exchange: Optional[Callable],
                    n_active: int) -> None:
    """After each lock-step verification round: gather every tile's bound,
    all-reduce across tiles (and the mesh, when configured), and push the
    result back into every still-running tile — including tiles of earlier
    partitions, whose queued candidates are re-pruned on their next step."""
    _count_round(plan, n_active)
    _exchange_bounds(plan, tiles, theta, bound_exchange,
                     tile_theta=lambda t: t.state.theta_lb,
                     raisable=lambda t: not t.state.finished())
    for t in tiles:
        if not t.state.finished():
            t.state.raise_theta(theta[t.qi])    # no-op unless higher


def _exchange_bounds(plan: ExecutionPlan, tiles, theta: np.ndarray,
                     bound_exchange: Optional[Callable],
                     tile_theta: Callable, raisable: Callable) -> None:
    """One exchange point: fold every tile's bound into the per-query
    ``theta`` vector (in place), all-reduce it, and account raises —
    ``bound_raises`` for each raisable tile whose own bound is below the
    exchanged one, ``backward_raises`` when the improving tile sits in a
    LATER partition than the raised one.  Both overlap exchange points
    (refinement-time and per verification round) share this accounting."""
    source_pi = {}
    for t in tiles:
        v = tile_theta(t)
        if v > theta[t.qi]:
            theta[t.qi] = v
            source_pi[t.qi] = t.pi
    new_theta = _exchange(theta, bound_exchange)
    for t in tiles:
        if raisable(t) and new_theta[t.qi] > tile_theta(t):
            plan.stats.bound_raises += 1
            if source_pi.get(t.qi, t.pi) > t.pi:
                plan.stats.backward_raises += 1
    theta[:] = new_theta
    plan.stats.theta_trace.append(theta.copy())


def _exchange(theta: np.ndarray,
              bound_exchange: Optional[Callable]) -> np.ndarray:
    if bound_exchange is None:
        return theta
    # max with the local bounds: the exchange may narrow dtypes (rounding
    # toward -inf to stay certified), and theta must never decrease
    return np.maximum(theta,
                      np.asarray(bound_exchange(theta), np.float64))
