"""Decoder-only LM assembly (dense / MoE / MLA / qk-norm families).

Layers are stacked (leading L dim on every leaf) and applied with
``lax.scan`` — this keeps the HLO size O(1) in depth (critical for 61-88
layer dry-run compiles) and is the idiom XLA pipelines best.  Remat policy
per config: 'full' (checkpoint everything at layer boundaries), 'dots'
(save MXU outputs), 'none'.

Three entry points per model:
  loss(params, batch)                          train_4k
  prefill(params, tokens[, prefix])            prefill_32k -> (logits, cache)
  decode_step(params, cache, token, index)     decode_32k / long_500k
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention, attention_init, blocked_xent, dtype_of,
                     embed, embed_init, rmsnorm, rmsnorm_init, softmax_xent,
                     swiglu, swiglu_init, unembed)
from .mla import mla_attention, mla_decode, mla_init
from .moe import moe_ffn, moe_init


# ----------------------------------------------------------------- layers

def _layer_init(key, cfg: ModelConfig, dtype, moe_layer: bool):
    ka, km = jax.random.split(key)
    p = {"attn_norm": rmsnorm_init(cfg.d_model, dtype),
         "mlp_norm": rmsnorm_init(cfg.d_model, dtype)}
    if cfg.mla is not None:
        p["attn"] = mla_init(ka, cfg, dtype)
    else:
        p["attn"] = attention_init(ka, cfg, dtype)
    if moe_layer:
        p["moe"] = moe_init(km, cfg.d_model, cfg.moe, dtype)
    else:
        p["mlp"] = swiglu_init(km, cfg.d_model, cfg.d_ff, dtype)
    return p


def _layer_apply(p, cfg: ModelConfig, x, positions, *, moe_layer: bool,
                 mask=None, cache=None, cache_index=None):
    h = rmsnorm(p["attn_norm"], x)
    if cfg.mla is not None:
        if cache is not None and cache_index is not None:
            a, new_cache = mla_decode(p["attn"], cfg, h, cache, cache_index,
                                      positions)
        else:
            a, new_cache = mla_attention(p["attn"], cfg, h, positions,
                                         mask=mask)
    else:
        a, new_cache = attention(p["attn"], cfg, h, positions, mask=mask,
                                 cache=cache, cache_index=cache_index)
    x = x + a
    h = rmsnorm(p["mlp_norm"], x)
    if moe_layer:
        f, aux = moe_ffn(p["moe"], h, cfg.moe)
    else:
        f, aux = swiglu(p["mlp"], h), {"lb_loss": jnp.float32(0.0)}
    return x + f, new_cache, aux


def _stack_init(key, cfg, dtype, n_layers: int, moe_layer: bool):
    keys = jax.random.split(key, max(n_layers, 1))
    layers = [_layer_init(k, cfg, dtype, moe_layer) for k in keys[:n_layers]]
    if not layers:
        return None
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan_stack(stacked, cfg, x, positions, *, moe_layer, mask):
    """Train/prefill scan over a homogeneous layer stack.  Returns
    (x, stacked caches, aux sum)."""
    def body(carry, layer_p):
        h = carry
        h, cache, aux = _layer_apply(layer_p, cfg, h, positions,
                                     moe_layer=moe_layer, mask=mask)
        return h, (cache, aux["lb_loss"])

    x, (caches, lb) = jax.lax.scan(_remat(body, cfg), x, stacked,
                                   unroll=cfg.scan_unroll)
    return x, caches, jnp.sum(lb)


def _scan_decode(stacked, cfg, x, positions, caches, cache_index, *,
                 moe_layer):
    def body(carry, xs):
        h = carry
        layer_p, cache = xs
        h, new_cache, _ = _layer_apply(layer_p, cfg, h, positions,
                                       moe_layer=moe_layer, cache=cache,
                                       cache_index=cache_index)
        return h, new_cache

    x, new_caches = jax.lax.scan(body, x, (stacked, caches),
                                 unroll=cfg.scan_unroll)
    return x, new_caches


# ------------------------------------------------------------------ model

class DecoderLM:
    """Decoder-only LM; families: dense, moe (w/ MLA), vlm (prefix stub)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = dtype_of(cfg.dtype)
        m = cfg.moe
        self.n_dense = (cfg.num_layers if m is None
                        else m.first_dense_layers)
        self.n_moe = 0 if m is None else cfg.num_layers - self.n_dense

    # -------------------------------------------------------------- params
    def init(self, key):
        cfg = self.cfg
        k0, k1, k2, k3 = jax.random.split(key, 4)
        params = {"embed": embed_init(k0, cfg.vocab_size, cfg.d_model,
                                      self.dtype),
                  "final_norm": rmsnorm_init(cfg.d_model, self.dtype)}
        if self.n_dense:
            params["dense_layers"] = _stack_init(k1, cfg, self.dtype,
                                                 self.n_dense, False)
        if self.n_moe:
            params["moe_layers"] = _stack_init(k2, cfg, self.dtype,
                                               self.n_moe, True)
        if not cfg.tie_embeddings:
            out = jax.random.normal(k3, (cfg.d_model, cfg.vocab_size),
                                    jnp.float32) * cfg.d_model ** -0.5
            params["out"] = {"table": out.T.astype(self.dtype)}
        return params

    def param_specs(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    # ------------------------------------------------------------- forward
    def _backbone(self, params, x, positions, mask):
        cfg = self.cfg
        lb_total = jnp.float32(0.0)
        caches = {}
        if self.n_dense:
            x, c, lb = _scan_stack(params["dense_layers"], cfg, x, positions,
                                   moe_layer=False, mask=mask)
            caches["dense"] = c
            lb_total += lb
        if self.n_moe:
            x, c, lb = _scan_stack(params["moe_layers"], cfg, x, positions,
                                   moe_layer=True, mask=mask)
            caches["moe"] = c
            lb_total += lb
        x = rmsnorm(params["final_norm"], x)
        return x, caches, lb_total

    def _logits(self, params, x):
        head = params["embed"] if self.cfg.tie_embeddings or \
            "out" not in params else params["out"]
        return unembed(head, x)

    def _embed_inputs(self, params, batch):
        """Tokens (+ optional modality-stub prefix embeddings)."""
        x = embed(params["embed"], batch["tokens"])
        if self.cfg.frontend is not None:
            x = jnp.concatenate(
                [batch["prefix"].astype(x.dtype), x], axis=1)
        return x

    # ---------------------------------------------------------------- loss
    def loss(self, params, batch):
        """batch: tokens (B,S) int32, labels (B,S) int32 (-100 = pad),
        optional prefix (B,F,d)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        x, _, lb = self._backbone(params, x, positions, None)
        if cfg.frontend is not None:        # loss only on the text region
            x = x[:, -batch["tokens"].shape[1]:]
        if cfg.xent_block:
            head = params["embed"] if cfg.tie_embeddings or \
                "out" not in params else params["out"]
            loss = blocked_xent(x[:, :-1], head["table"],
                                batch["labels"][:, 1:], cfg.xent_block)
        else:
            logits = self._logits(params, x)
            loss = softmax_xent(logits[:, :-1], batch["labels"][:, 1:])
        return loss + 0.01 * lb

    # ------------------------------------------------------------- serving
    def cache_specs(self, batch: int, max_seq: int):
        cfg = self.cfg
        dt = self.dtype

        def attn_cache(n):
            if cfg.mla is not None:
                return {"ckv": jax.ShapeDtypeStruct(
                            (n, batch, max_seq, cfg.mla.kv_lora_rank), dt),
                        "k_rope": jax.ShapeDtypeStruct(
                            (n, batch, max_seq, cfg.mla.qk_rope_dim), dt)}
            return {"k": jax.ShapeDtypeStruct(
                        (n, batch, max_seq, cfg.num_kv_heads, cfg.hd), dt),
                    "v": jax.ShapeDtypeStruct(
                        (n, batch, max_seq, cfg.num_kv_heads, cfg.hd), dt)}

        specs = {}
        if self.n_dense:
            specs["dense"] = attn_cache(self.n_dense)
        if self.n_moe:
            specs["moe"] = attn_cache(self.n_moe)
        return specs

    def init_cache(self, batch: int, max_seq: int):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_specs(
                batch, max_seq))

    def prefill(self, params, batch, max_seq: Optional[int] = None):
        """Full-sequence forward; returns (last logits, cache padded to
        max_seq)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :].repeat(B, 0)
        x, caches, _ = self._backbone(params, x, positions, None)
        logits = self._logits(params, x[:, -1:])
        if max_seq is not None and max_seq > S:
            def pad(c):
                return jnp.pad(
                    c, [(0, 0), (0, 0), (0, max_seq - S)]
                    + [(0, 0)] * (c.ndim - 3))
            caches = jax.tree_util.tree_map(pad, caches)
        return logits, caches

    def decode_step(self, params, caches, token, cache_index):
        """token (B,1) int32; caches as from prefill/init_cache;
        cache_index: scalar int32 position to write."""
        cfg = self.cfg
        x = embed(params["embed"], token)
        B = x.shape[0]
        positions = jnp.full((B, 1), cache_index, jnp.int32)
        new = {}
        if self.n_dense:
            x, c = _scan_decode(params["dense_layers"], cfg, x, positions,
                                caches["dense"], cache_index,
                                moe_layer=False)
            new["dense"] = c
        if self.n_moe:
            x, c = _scan_decode(params["moe_layers"], cfg, x, positions,
                                caches["moe"], cache_index, moe_layer=True)
            new["moe"] = c
        x = rmsnorm(params["final_norm"], x)
        return self._logits(params, x), new
