"""End-to-end driver: train a ~100M-param LM for a few hundred steps, then
use its embedding table as the KOIOS similarity provider.

This is the full production loop of the framework: data pipeline ->
distributed train step (same code path as the 256-chip mesh) -> rolling
checkpoints -> tower embeddings -> semantic search.

    PYTHONPATH=src python examples/train_embeddings.py [--steps 200]
"""
import argparse

import numpy as np

from repro.core import EmbeddingSimilarity, KoiosSearch, SearchParams
from repro.data import make_collection, sample_queries
from repro.data.embeddings import tower_embeddings
from repro.checkpoint import CheckpointManager
from repro.launch.train import train
from repro.models import ModelConfig


def hundred_m_config():
    """~100M params: 8L d=512 8H ff=2048 vocab=32000 (llama-style)."""
    return ModelConfig(name="lm-100m", family="dense", num_layers=8,
                       d_model=512, num_heads=8, num_kv_heads=4,
                       d_ff=2048, vocab_size=32000, dtype="float32",
                       remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/koios_100m")
    args = ap.parse_args()

    # register the config on the fly so the standard driver runs it
    import repro.configs.registry as reg
    import types
    mod = types.ModuleType("lm_100m")
    mod.CONFIG = hundred_m_config()
    mod.smoke_config = hundred_m_config
    import sys
    sys.modules["repro.configs.lm_100m"] = mod
    reg.ARCHS["lm-100m"] = "lm_100m"

    print(f"[1/3] training ~100M LM for {args.steps} steps "
          f"(batch={args.batch}, seq={args.seq})")
    losses = train(["--arch", "lm-100m", "--steps", str(args.steps),
                    "--batch", str(args.batch), "--seq", str(args.seq),
                    "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
                    "--log-every", "20"])
    print(f"    loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("[2/3] extracting tower embeddings from the checkpoint")
    mgr = CheckpointManager(args.ckpt_dir)
    _, state, _ = mgr.restore_latest()
    table = tower_embeddings(state["params"])

    print("[3/3] semantic search with the trained similarity")
    coll = make_collection(num_sets=400, vocab_size=table.shape[0],
                           avg_size=10, max_size=30, seed=1)
    engine = KoiosSearch(coll, EmbeddingSimilarity(table),
                         SearchParams(k=5, alpha=0.8))
    q = sample_queries(coll, 1, seed=2)[0]
    res = engine.search(q)
    print(f"    top-5: ids={res.ids.tolist()} "
          f"scores={[round(float(s),2) for s in res.lb]}")
    print(f"    stats: {res.stats.as_dict()}")


if __name__ == "__main__":
    main()
