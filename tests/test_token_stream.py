"""Token stream & inverted index invariants (paper §IV)."""
import numpy as np

from repro.core import InvertedIndex, build_token_stream, expand_to_events
from repro.core.token_stream import pad_events
from repro.data import sample_queries


def test_stream_complete_and_sorted(small_world):
    """Every (q, t) pair with sim >= alpha appears exactly once, descending."""
    coll, sim = small_world
    q = sample_queries(coll, 1, seed=3)[0]
    alpha = 0.8
    stream = build_token_stream(q, sim, alpha)
    # descending
    assert np.all(np.diff(stream.sim) <= 1e-6)
    assert np.all(stream.sim >= alpha - 1e-6)
    # completeness vs dense similarity
    dense = np.asarray(sim.pairwise(q, np.arange(coll.vocab_size)))
    qi, tj = np.nonzero(dense >= alpha)
    want = set(zip(qi.tolist(), tj.tolist()))
    got = set(zip(stream.q_pos.tolist(), stream.token.tolist()))
    assert want == got
    # identity pairs carry sim exactly 1
    ident = q[stream.q_pos] == stream.token
    assert np.all(stream.sim[ident] == 1.0)


def test_inverted_index_roundtrip(small_world):
    coll, _ = small_world
    inv = InvertedIndex.build(coll)
    assert inv.total_postings == coll.total_tokens
    # spot-check: postings of token t are exactly the sets containing t
    rng = np.random.default_rng(0)
    for t in rng.integers(0, coll.vocab_size, 20):
        sets, slots = inv.postings(int(t))
        expect = [i for i in range(coll.num_sets)
                  if t in coll.get_set(i)]
        assert sorted(sets.tolist()) == expect
        # slots point back at this token in the flat array
        assert np.all(coll.set_tokens[slots] == t)


def test_event_expansion(small_world):
    coll, sim = small_world
    inv = InvertedIndex.build(coll)
    q = sample_queries(coll, 1, seed=5)[0]
    stream = build_token_stream(q, sim, 0.8)
    ev = expand_to_events(stream, inv)
    # events remain descending and reference valid sets
    assert np.all(np.diff(ev.sim) <= 1e-6)
    assert ev.set_id.min() >= 0 and ev.set_id.max() < coll.num_sets
    # event count == sum of posting counts over stream tokens
    counts = inv.posting_counts()
    assert len(ev) == int(counts[stream.token].sum())


def test_pad_events_pow2(small_world):
    coll, sim = small_world
    inv = InvertedIndex.build(coll)
    q = sample_queries(coll, 1, seed=5)[0]
    ev = expand_to_events(build_token_stream(q, sim, 0.8), inv)
    s, qp, sl, si = pad_events(ev, 64)
    n_chunks = s.shape[0]
    assert n_chunks & (n_chunks - 1) == 0          # power of two
    assert s.shape == qp.shape == sl.shape == si.shape
    flat = s.reshape(-1)
    assert np.all(flat[len(ev):] == -1)            # padding sentinel
    # padded sims keep the stream's final value (valid s_now)
    assert np.all(si.reshape(-1)[len(ev):] == ev.sim[-1])


def test_kernel_stream_parity(small_world):
    """``use_kernel=True`` routes the stream sweep through the
    ``cosine_topk`` Pallas kernel (interpret mode on CPU); the resulting
    streams must be bit-identical to the jnp provider path — same tuples,
    same values, same order (admission order is load-bearing)."""
    from repro.core.token_stream import build_token_stream_batch

    coll, sim = small_world
    queries = sample_queries(coll, 4, seed=5)
    for alpha in (0.8, 0.95):
        provider = build_token_stream_batch(queries, sim, alpha)
        kernel = build_token_stream_batch(queries, sim, alpha,
                                          use_kernel=True)
        for a, b in zip(provider, kernel):
            assert np.array_equal(a.q_pos, b.q_pos)
            assert np.array_equal(a.token, b.token)
            assert np.array_equal(a.sim, b.sim)


def test_kernel_stream_end_to_end(small_world):
    """A full engine run with ``stream_use_kernel`` returns bit-identical
    results (the stream feeds every downstream bound)."""
    from repro.core import KoiosSearch, SearchParams

    coll, sim = small_world
    queries = sample_queries(coll, 3, seed=17)
    base = KoiosSearch(coll, sim, SearchParams(k=5, alpha=0.8, chunk_size=64,
                                               verify_batch=8), partitions=2)
    kern = KoiosSearch(coll, sim, SearchParams(k=5, alpha=0.8, chunk_size=64,
                                               verify_batch=8,
                                               stream_use_kernel=True),
                       partitions=2)
    for a, b in zip(base.search_batch(queries), kern.search_batch(queries)):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.lb, b.lb)
        assert np.array_equal(a.ub, b.ub)
