"""KOIOS core — top-k semantic overlap set search (the paper's contribution).

Public API:
    SetCollection, SearchParams, SearchResult   (types)
    EmbeddingSimilarity, NGramJaccardSimilarity (similarity providers)
    KoiosSearch, KoiosIndex                     (search engine)
    baseline_topk, baseline_plus_topk, brute_force_topk (paper baselines)
"""
from .types import (SetCollection, SearchParams, SearchResult,
                    SearchStats, QueryValidationError, validate_query)
from .similarity import EmbeddingSimilarity, NGramJaccardSimilarity
from .inverted_index import InvertedIndex
from .token_stream import (TokenStreamCache, build_token_stream,
                           build_token_stream_batch,
                           build_token_stream_batch_cached, expand_to_events)
from .scheduler import (ExecutionPlan, SchedulerStats, run_plan,
                        run_fused_wave, run_wave)
from .search import (KoiosSearch, KoiosIndex, build_partition_indexes,
                     partition_ranges, search_partition,
                     search_partition_batch, merge_topk, merge_topk_batch)
from .baseline import baseline_topk, baseline_plus_topk, brute_force_topk

__all__ = [
    "SetCollection", "SearchParams", "SearchResult", "SearchStats",
    "QueryValidationError", "validate_query",
    "EmbeddingSimilarity", "NGramJaccardSimilarity", "InvertedIndex",
    "TokenStreamCache", "build_token_stream", "build_token_stream_batch",
    "build_token_stream_batch_cached", "expand_to_events",
    "ExecutionPlan", "SchedulerStats", "run_plan", "run_fused_wave",
    "run_wave",
    "KoiosSearch", "KoiosIndex", "build_partition_indexes",
    "partition_ranges", "search_partition", "search_partition_batch",
    "merge_topk", "merge_topk_batch",
    "baseline_topk", "baseline_plus_topk", "brute_force_topk",
]
