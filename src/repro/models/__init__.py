from .config import (HybridConfig, MLAConfig, MoEConfig, ModelConfig,
                     SHAPES, SSMConfig)
from .model import build, input_specs, shape_applicable, shape_kind

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
           "HybridConfig", "SHAPES", "build", "input_specs",
           "shape_applicable", "shape_kind"]
