"""Pallas TPU kernel: the auction bidding round's heavy pass.

One synchronous auction round (``repro.core.matching.auction``) is dominated
by the profit top-2 reduction:  profits = w - prices,  then per row the best
value/column and the runner-up.  This kernel fuses subtract + top-2 so the
(n, m) profit matrix never materializes in HBM — the weight tile streams
HBM->VMEM once and only three (n,) vectors come back.

Grid: row tiles of ``bn``.  Prices live in a (1, m) block with a constant
index map (resident across the sweep).  Outputs are (n, 1) column vectors
(2-D for TPU layout friendliness); the ops wrapper squeezes them.

VMEM per step: bn*m (weights) + m (prices) + bn*m (profit tile, fused) —
bn=256, m=2048 f32 => ~4 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30  # python scalar: jnp constants may not be closure-captured by kernels


def _kernel(wm_ref, p_ref, w1_ref, w2_ref, j_ref):
    profits = wm_ref[...] - p_ref[...]              # (bn, m)
    w1 = jnp.max(profits, axis=1, keepdims=True)    # (bn, 1)
    jstar = jnp.argmax(profits, axis=1).astype(jnp.int32)[:, None]
    cols = jax.lax.broadcasted_iota(jnp.int32, profits.shape, 1)
    second = jnp.where(cols == jstar, _NEG, profits)
    w2 = jnp.max(second, axis=1, keepdims=True)
    w1_ref[...] = w1
    w2_ref[...] = w2
    j_ref[...] = jstar


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def auction_topk2(wm: jnp.ndarray, prices: jnp.ndarray, bn: int = 256,
                  interpret: bool = False):
    """Per-row (best, second-best) profit and best column.

    wm: (n, m) weights;  prices: (m,).  Returns (w1 (n,), w2 (n,),
    jstar (n,) int32).  Rows whose profits are all equal get w2 == w1's
    runner-up under first-index argmax tie-breaking (matches the oracle).
    """
    n, m = wm.shape
    n_pad = -(-n // bn) * bn
    if n_pad != n:
        wm = jnp.pad(wm, ((0, n_pad - n), (0, 0)), constant_values=_NEG)
    grid = (n_pad // bn,)
    w1, w2, jstar = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(wm.astype(jnp.float32), prices.astype(jnp.float32)[None, :])
    return w1[:n, 0], w2[:n, 0], jstar[:n, 0]
