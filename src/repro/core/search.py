"""KoiosSearch — end-to-end top-k semantic overlap search (paper Fig. 2).

Pipeline per (query x partition) tile:
    token stream (blocked sim matmul, one stacked sweep per request batch)
    ->  event expansion (inverted index)  ->  refinement (chunked
    vectorized filters)  ->  post-processing (No-EM + batched verification
    w/ Lemma-8 early termination).

All execution — single query, request batch, partitioned repository — is
one :class:`repro.core.scheduler.ExecutionPlan` driven by the partition
scheduler: ``search`` IS ``search_batch`` with B=1 IS the scheduler with
P=1.  The default ``overlap`` schedule runs every tile concurrently (async
refinement dispatch, one global cross-partition/cross-query verification
queue, bidirectional theta_lb feedback); ``sequential`` replays the
paper's host loop over partitions with the running-max shared bound —
both return bit-identical exact results (asserted in
tests/test_scheduler.py).  On a device mesh the per-round bound exchange
is an all-reduce-max over the (pod, data) axes (``bound_exchange``; see
``repro.runtime.sharding.all_reduce_max`` and DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import numpy as np

from .inverted_index import InvertedIndex
from .scheduler import ExecutionPlan, SchedulerStats, run_plan
from .types import (SearchParams, SearchResult, SearchStats, SetCollection,
                    validate_query)


@dataclasses.dataclass
class KoiosIndex:
    """Prebuilt indexes for one partition of the repository."""

    coll: SetCollection
    inv: InvertedIndex
    id_offset: int = 0      # global id of the partition's first set

    @staticmethod
    def build(coll: SetCollection, id_offset: int = 0) -> "KoiosIndex":
        return KoiosIndex(coll=coll, inv=InvertedIndex.build(coll),
                          id_offset=id_offset)


def search_partition(index: KoiosIndex, query: np.ndarray, sim_provider,
                     params: SearchParams,
                     theta_lb0: float = 0.0) -> SearchResult:
    """One query against one partition (compatibility wrapper: a 1x1
    plan); ``theta_lb0`` is the shared global bound."""
    return search_partition_batch(index, [query], sim_provider, params,
                                  [theta_lb0])[0]


def search_partition_batch(index: KoiosIndex, queries: Sequence[np.ndarray],
                           sim_provider, params: SearchParams,
                           theta_lb0s: Sequence[float]
                           ) -> "list[SearchResult]":
    """B queries against one partition (compatibility wrapper: a Bx1 plan
    on the sequential drive — with a single partition the schedules
    coincide).  Per-query results are bit-identical to B
    :func:`search_partition` calls."""
    plan = ExecutionPlan([index], queries, pool_coll=index.coll,
                         theta0=theta_lb0s, request_id_bases=[0])
    return [rs[0] for rs in
            run_plan(plan, sim_provider, params, schedule="sequential")]


def partition_ranges(set_sizes: np.ndarray, partitions: int,
                     by: str = "sets") -> np.ndarray:
    """Contiguous partition boundaries over the repository (paper §VI).

    ``by='sets'``: equal set counts (``np.linspace`` — the historical
    default).  ``by='tokens'``: greedy token-count balancer (DESIGN.md §9
    item 5, resolved): walk the prefix token counts and cut at whichever
    set boundary lands nearest each i/P share of the total, so every
    partition's token count is within half the largest set of the ideal
    share.  Balanced *work* per partition is what keeps fused waves
    uniform enough to overlap (LES3 makes the same observation for
    partition-quality -> exact-search cost).

    The token path NEVER emits an empty partition: when ``partitions >=
    num_sets`` it degenerates to one set per partition (``partitions``
    ranges cannot all be non-empty, so fewer bounds are returned rather
    than duplicated ones — an empty range would otherwise become a
    zero-row tile occupying a wave slot), and below that the forward +
    backward collision passes guarantee strictly increasing bounds even
    when one huge set drags every greedy cut to the same boundary."""
    n = len(set_sizes)
    if by == "sets":
        return np.linspace(0, n, partitions + 1).astype(int)
    assert by == "tokens", f"unknown partitioning {by!r}"
    if partitions >= n:
        # Degenerate split (partitions approaching/exceeding the set
        # count): the greedy balancer would collide every cut on the few
        # set boundaries available and the collision passes would clamp
        # into duplicated bounds — i.e. empty partitions.  One set per
        # partition is the only non-empty maximal split; callers see
        # len(bounds)-1 <= partitions ranges, all non-empty.
        return np.arange(n + 1, dtype=int)
    cum = np.concatenate([[0], np.cumsum(set_sizes, dtype=np.int64)])
    targets = cum[-1] * np.arange(1, partitions) / partitions
    cuts = np.searchsorted(cum, targets)
    # nearest set boundary to each target (greedy balance, then monotone)
    cuts = np.where(
        np.abs(cum[np.maximum(cuts - 1, 0)] - targets)
        <= np.abs(cum[np.minimum(cuts, n)] - targets),
        np.maximum(cuts - 1, 0), np.minimum(cuts, n))
    bounds = np.concatenate([[0], cuts, [n]]).astype(int)
    # non-empty partitions: the forward pass pushes collided cuts right
    # (clamped at n), the backward pass pulls the clamped tail left — a
    # single huge set can drag every greedy cut to n, and only the pair
    # of passes guarantees strictly increasing bounds for P < num_sets
    for i in range(1, len(bounds)):
        bounds[i] = min(max(bounds[i], bounds[i - 1] + 1), n)
    for i in range(len(bounds) - 2, 0, -1):
        bounds[i] = min(bounds[i], bounds[i + 1] - 1)
    assert np.all(np.diff(bounds) > 0), bounds   # every partition non-empty
    return bounds


def build_partition_indexes(coll: SetCollection, partitions: int,
                            by: str = "sets") -> "list[KoiosIndex]":
    """Build the per-partition indexes of a repository split — THE
    partitioning used by every serving entry point (``KoiosSearch`` and
    the request engine share it, so their plans decompose identically —
    a precondition of the engine == one-shot bit-identity).

    Since the collection became a first-class resource this is a thin
    wrapper over :meth:`repro.runtime.collection.ShardedCollection.build`:
    the returned indexes ARE that resource's :class:`Shard`s, so callers
    holding a bare index list still borrow (never own) device state."""
    from ..runtime.collection import ShardedCollection

    return ShardedCollection.build(coll, partitions, by=by).shards


_I32_MAX = np.int32(np.iinfo(np.int32).max)


@functools.lru_cache(maxsize=None)
def _merge_tree_fn(B_pad: int, P_pad: int, k: int):
    """Jitted device-side log-depth top-k merge tree for a static
    (B_pad, P_pad, k) geometry (pow2-padded: O(log) compiled variants).

    Each level pairs adjacent partitions' k-lists, sorts each 2k-row
    lexicographically ascending by (key, seq) with ``jax.lax.sort``
    (num_keys=2), and keeps the first k — the top-k of a union is the
    top-k of the unions' top-ks, so log2(P_pad) levels reproduce the
    global order exactly.  ``key = -(lb + 0.0)`` makes ascending-key
    order equal descending-lb order while canonicalizing -0.0 to +0.0
    (numpy's stable argsort treats the two zeros as equal ties broken by
    position; lax.sort's total order would otherwise put -0.0 first),
    and ``seq`` — the entry's position in the partition-order
    concatenation — breaks ties exactly like ``np.argsort(-lb,
    kind='stable')``.  Pads carry lb=-inf (key=+inf) and seq=INT32_MAX,
    so they sort after every real entry at every level."""
    import jax
    import jax.numpy as jnp

    def fn(lb, ub, ids, seq):
        key = jnp.negative(lb + jnp.float32(0.0))
        ops = (key, seq, lb, ub, ids)
        p = P_pad
        while p > 1:
            ops = tuple(x.reshape(B_pad, p // 2, 2 * k) for x in ops)
            ops = jax.lax.sort(ops, dimension=2, num_keys=2)
            ops = tuple(x[:, :, :k] for x in ops)
            p //= 2
        if P_pad == 1:           # no pairing level ran: sort the one list
            ops = jax.lax.sort(ops, dimension=2, num_keys=2)
        _, _, lb, ub, ids = (x.reshape(B_pad, k) for x in ops)
        return lb, ub, ids

    return jax.jit(fn)


def _merge_stats(results: Sequence[SearchResult]) -> SearchStats:
    """Host-side per-query stats fold (sums; theta_lb_final is a max)."""
    stats = SearchStats()
    for r in results:
        for f, v in r.stats.as_dict().items():
            setattr(stats, f, getattr(stats, f) + v if f != "theta_lb_final"
                    else max(getattr(stats, f), v))
    return stats


def merge_topk_batch(per_query: Sequence[Sequence[SearchResult]],
                     k: int) -> "list[SearchResult]":
    """Merge every query's per-partition top-k lists through ONE
    device-side log-depth reduction tree dispatch (paper:
    'merge-sorted'; DESIGN.md §5).

    Bit-identical to the historical host merge —
    ``np.argsort(-lb, kind='stable')[:k]`` over the partition-order
    concatenation — because the tree's (key, seq) total order IS that
    stable order (see :func:`_merge_tree_fn`); only each partition's
    first k entries enter the tree (a sorted partition list's k+1-th
    entry is preceded by k same-partition entries of >= lb and smaller
    seq, so it can never reach the global top-k).  Stats merge on host:
    they are O(P) scalars and schedule bookkeeping, not ranking state."""
    from ..runtime import instrument
    from .types import pow2

    B = len(per_query)
    if B == 0:
        return []
    P = max(len(rs) for rs in per_query)
    B_pad, P_pad = pow2(max(B, 1)), pow2(max(P, 1))
    lb = np.full((B_pad, P_pad, k), -np.inf, np.float32)
    ub = np.full((B_pad, P_pad, k), -np.inf, np.float32)
    ids = np.full((B_pad, P_pad, k), -1, np.int32)
    seq = np.full((B_pad, P_pad, k), _I32_MAX, np.int32)
    totals = np.zeros(B, np.int64)
    for qi, rs in enumerate(per_query):
        off = 0
        for pi, r in enumerate(rs):
            m = min(len(r.lb), k)
            lb[qi, pi, :m] = r.lb[:m]
            ub[qi, pi, :m] = r.ub[:m]
            ids[qi, pi, :m] = r.ids[:m]
            seq[qi, pi, :m] = off + np.arange(m)
            off += len(r.lb)     # seq keeps FULL concatenation positions
        totals[qi] = off
    instrument.record("h2d:topk_merge")
    m_lb, m_ub, m_ids = _merge_tree_fn(B_pad, P_pad, k)(lb, ub, ids, seq)
    instrument.record("d2h:topk_merge")
    m_lb, m_ub, m_ids = (np.asarray(x) for x in (m_lb, m_ub, m_ids))
    out = []
    for qi, rs in enumerate(per_query):
        n = int(min(k, totals[qi]))
        out.append(SearchResult(
            ids=m_ids[qi, :n], lb=m_lb[qi, :n], ub=m_ub[qi, :n],
            stats=_merge_stats(rs)))
    return out


def merge_topk(results: Sequence[SearchResult], k: int) -> SearchResult:
    """Merge one query's per-partition top-k lists — the B=1 case of
    :func:`merge_topk_batch` (same device reduction tree)."""
    return merge_topk_batch([results], k)[0]


class KoiosSearch:
    """Public search API over a (possibly partitioned) repository.

    ``schedule`` selects the default drive order of the partition
    scheduler: 'fused' (default — the on-device wave pipeline where it
    can run, resolving to 'overlap' off-TPU unless ``params.fused ==
    'interpret'``), 'overlap', or 'sequential'; all are exact and
    bit-identical.  ``partition_by`` picks the repository split:
    'sets' (equal set counts) or 'tokens' (greedy token-count balance —
    see :func:`partition_ranges`).  ``bound_exchange`` optionally plugs a
    mesh all-reduce-max into the per-round theta_lb exchange (see
    ``repro.runtime.sharding.all_reduce_max``); ``mesh`` additionally
    moves the fused schedule's exchange on-device.  ``scheduler_stats``
    holds the :class:`SchedulerStats` of the most recent call.
    ``stream_cache`` optionally plugs a
    :class:`~repro.core.token_stream.TokenStreamCache` into the one-shot
    path: repeated queries skip the blocked stream sweep (bit-identical
    streams, DESIGN.md §3.2) — the request engine's cache layer,
    available without the engine.

    Collection state lives in a
    :class:`~repro.runtime.collection.ShardedCollection` resource, NOT
    here: pass ``collection=`` to serve an existing (possibly placed)
    resource — sharing its device-resident operands with every other
    consumer — or let the constructor build a private one from ``coll``
    (``partitions``/``partition_by`` become the shard split).  Either
    way ``KoiosSearch`` only borrows per-shard operand views; it owns no
    device arrays, so N search objects over one resource pay for one
    upload of everything (DESIGN.md §5).
    """

    def __init__(self, coll: Optional[SetCollection], sim_provider,
                 params: Optional[SearchParams] = None,
                 partitions: int = 1, schedule: str = "fused",
                 bound_exchange: Optional[Callable] = None,
                 partition_by: str = "sets", mesh=None,
                 stream_cache=None, collection=None):
        from ..runtime.collection import ShardedCollection

        self.params = params or SearchParams()
        self.sim = sim_provider
        if collection is None:
            collection = ShardedCollection.build(coll, partitions,
                                                 by=partition_by)
        self.collection = collection
        self.schedule = schedule
        self.bound_exchange = bound_exchange
        self.mesh = mesh
        self.stream_cache = stream_cache
        self.scheduler_stats: Optional[SchedulerStats] = None

    # head-epoch delegation (DESIGN.md §6.5): a one-shot search always
    # sees the latest committed repository; each search_batch call pins
    # the head for its own duration so a concurrent commit cannot tear it
    @property
    def coll(self) -> SetCollection:
        return self.collection.coll

    @property
    def partitions(self):
        return self.collection.shards

    def search(self, query: np.ndarray, k: Optional[int] = None,
               schedule: Optional[str] = None) -> SearchResult:
        """Single-query search == ``search_batch`` with B=1."""
        return self.search_batch([query], k=k, schedule=schedule)[0]

    def search_batch(self, queries: Sequence[np.ndarray],
                     k: Optional[int] = None,
                     schedule: Optional[str] = None
                     ) -> "list[SearchResult]":
        """Search B queries — one execution plan, every (query x
        partition) tile through the shared pipeline.

        Results are exact and independent of the schedule and of the
        batch composition: ``search_batch(qs)[i]`` is bit-identical to
        ``search(qs[i])`` (same ids, same lb/ub floats — and on the
        default schedule the same per-phase statistics).
        """
        params = self.params if k is None else dataclasses.replace(
            self.params, k=k)
        queries = [validate_query(q, self.sim) for q in queries]
        if not queries:
            return []
        # pin the head epoch for the call: the whole plan computes
        # against one consistent snapshot even if a live-update commit
        # lands mid-search (the one-shot counterpart of the engine's
        # admission pinning, DESIGN.md §6.5)
        epoch = self.collection.pin()
        try:
            streams = None
            if self.stream_cache is not None:
                from .token_stream import build_token_stream_batch_cached
                self.stream_cache.set_epoch(epoch.epoch)
                streams = build_token_stream_batch_cached(
                    queries, self.sim, params.alpha, self.stream_cache,
                    use_kernel=params.stream_use_kernel)
            plan = ExecutionPlan(epoch.shards, queries,
                                 pool_coll=epoch.coll, epoch=epoch.epoch)
            per_query = run_plan(plan, self.sim, params,
                                 schedule=schedule or self.schedule,
                                 bound_exchange=self.bound_exchange,
                                 mesh=self.mesh, streams=streams)
            self.scheduler_stats = plan.stats
        finally:
            self.collection.release(epoch)
        # ONE device dispatch merges every query's per-shard top-k lists
        # through the log-depth reduction tree (bit-identical to the
        # historical host concatenation merge — see merge_topk_batch)
        return merge_topk_batch(per_query, params.k)
