"""Fault tolerance & elasticity: the control-plane state machine.

On real multi-host TPU fleets, failure detection is heartbeat-driven and
the recovery path is: quiesce -> choose largest healthy mesh -> restore the
latest checkpoint with the new sharding -> resume (the data pipeline is a
pure function of the step counter, so no data is lost or repeated).  This
module implements that state machine host-side so it is unit-testable in
this single-process container; the mesh-building and resharding pieces it
drives (launch/mesh.py, checkpoint/) are the real ones.

Straggler mitigation: per-step host heartbeats; hosts whose step latency
exceeds ``straggler_factor`` x the fleet median for ``patience``
consecutive steps are reported for eviction (the same quiesce/re-mesh path
as a failure, minus the lost shard).

The SERVING plane reuses the same machinery (DESIGN.md §6): every
``RequestEngine.step`` heartbeats into a :class:`FleetMonitor`, the
admission router quarantines replicas that raise, straggle, or hang, and
:class:`FaultPlan` is the deterministic, seeded fault injector (crash at
a step, stall for a duration, transient verifier error) that tests and
``benchmarks/soak.py`` drive the whole recovery path with."""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Dict, Iterable, List, Optional, Tuple


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    last_step: int
    step_latency: float = 0.0
    healthy: bool = True
    epoch: int = -1                # collection epoch served (-1 = unknown)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    heartbeat_timeout: float = 60.0
    straggler_factor: float = 2.0
    straggler_patience: int = 3


class FleetMonitor:
    """Tracks host heartbeats; decides failure/straggler evictions and the
    replacement mesh shape."""

    def __init__(self, num_hosts: int, cfg: FaultConfig = FaultConfig(),
                 clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, clock(), -1) for h in range(num_hosts)}
        self._strag_count: Dict[int, int] = {h: 0 for h in range(num_hosts)}

    def heartbeat(self, host_id: int, step: int, step_latency: float,
                  epoch: Optional[int] = None):
        """``epoch`` (optional — training-substrate callers don't serve a
        collection) reports which collection epoch the host serves, so
        the rollout's progress is visible in the health plane."""
        hs = self.hosts[host_id]
        hs.last_heartbeat = self.clock()
        hs.last_step = step
        hs.step_latency = step_latency
        if epoch is not None:
            hs.epoch = int(epoch)

    def failed_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, hs in self.hosts.items()
                if hs.healthy and now - hs.last_heartbeat
                > self.cfg.heartbeat_timeout]

    def stragglers(self) -> List[int]:
        healthy = [hs for hs in self.hosts.values() if hs.healthy]
        lats = sorted(hs.step_latency for hs in healthy if hs.step_latency)
        if len(lats) < 2:
            return []
        median = lats[len(lats) // 2]
        out = []
        for hs in healthy:
            if hs.step_latency > self.cfg.straggler_factor * median:
                self._strag_count[hs.host_id] += 1
                if self._strag_count[hs.host_id] >= \
                        self.cfg.straggler_patience:
                    out.append(hs.host_id)
            else:
                self._strag_count[hs.host_id] = 0
        return out

    def evict(self, host_ids: List[int]):
        for h in host_ids:
            self.hosts[h].healthy = False
            self._strag_count[h] = 0

    def restore(self, host_id: int):
        """Recovery to healthy: a quarantined host that passed its probe
        re-enters the fleet with a fresh heartbeat and a clean straggler
        record (its pre-eviction latency history must not re-evict it)."""
        hs = self.hosts[host_id]
        hs.healthy = True
        hs.last_heartbeat = self.clock()
        hs.step_latency = 0.0
        self._strag_count[host_id] = 0

    def healthy_count(self) -> int:
        return sum(hs.healthy for hs in self.hosts.values())


def plan_elastic_mesh(healthy_chips: int,
                      model_axis: int) -> Optional[Tuple[int, ...]]:
    """Largest (data, model) mesh that fits the healthy chips, keeping the
    model axis intact (TP degree is fixed by the memory plan) and the data
    axis a power of two (keeps global batch divisible)."""
    if healthy_chips < model_axis:
        return None
    data = healthy_chips // model_axis
    p = 1
    while p * 2 <= data:
        p *= 2
    return (p, model_axis)


# --------------------------------------------------------------------------
# Serving-plane fault injection (DESIGN.md §6).
#
# The request engine consumes these: a FaultPlan is attached to a replica
# (replica index + engine step address each event), and the engine turns
# the event into the corresponding failure at the top of / inside its
# step.  The plan is plain data — deterministic, seed-buildable, and
# inspectable after the run (``fired``) — so a soak run with faults is
# exactly reproducible.


class ReplicaCrash(RuntimeError):
    """Hard failure of one engine replica: the step never returns.  The
    router's recovery path treats it as permanent (no revival)."""


class TransientVerifierError(RuntimeError):
    """A verification wave failed transiently (the cubic-cost stage the
    paper's filters protect is also the longest-running, most
    preemptible one).  The replica is quarantined but revivable."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault: fire ``kind`` on ``replica``'s ``step``-th
    engine step (steps count from 1).  ``stall_s`` is the injected delay
    for ``kind='stall'``; a stall longer than the fleet's heartbeat
    timeout is a hang (missed-heartbeat quarantine), shorter repeated
    stalls trip the straggler detector."""

    kind: str                      # 'crash' | 'stall' | 'verify_error'
    replica: int
    step: int
    stall_s: float = 0.0

    def __post_init__(self):
        assert self.kind in ("crash", "stall", "verify_error"), self.kind


class FaultPlan:
    """A deterministic fault schedule over a replica fleet.

    Build explicitly from events (tests pin exact scenarios) or draw a
    reproducible schedule from a seed (``FaultPlan.random`` — the soak
    harness).  ``take(replica, step)`` pops the events due at that
    address; each event fires exactly once and is appended to ``fired``
    (the audit trail benchmarks report)."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self._due: Dict[Tuple[int, int], List[FaultEvent]] = {}
        self.events = sorted(events, key=lambda e: (e.step, e.replica))
        for e in self.events:
            self._due.setdefault((e.replica, e.step), []).append(e)
        self.fired: List[FaultEvent] = []

    @classmethod
    def random(cls, seed: int, replicas: int, steps: int,
               crashes: int = 1, stalls: int = 1, verify_errors: int = 1,
               stall_s: float = 0.05, protect: Tuple[int, ...] = ()
               ) -> "FaultPlan":
        """Seeded schedule: ``crashes`` distinct replicas crash (never
        the ones in ``protect``, and never all replicas), plus ``stalls``
        and ``verify_errors`` spread over the remaining fleet."""
        rng = random.Random(seed)
        victims = [r for r in range(replicas) if r not in protect]
        rng.shuffle(victims)
        crashes = min(crashes, max(len(victims) - 1, 0))
        events = [FaultEvent("crash", victims[i],
                             rng.randrange(2, max(steps, 3)))
                  for i in range(crashes)]
        survivors = victims[crashes:] or victims[:1]
        for _ in range(stalls):
            events.append(FaultEvent("stall", rng.choice(survivors),
                                     rng.randrange(1, max(steps, 2)),
                                     stall_s=stall_s))
        for _ in range(verify_errors):
            events.append(FaultEvent("verify_error", rng.choice(survivors),
                                     rng.randrange(1, max(steps, 2))))
        return cls(events)

    def take(self, replica: int, step: int) -> List[FaultEvent]:
        due = self._due.pop((replica, step), [])
        self.fired.extend(due)
        return due

    def pending(self) -> int:
        return sum(len(v) for v in self._due.values())

    def describe(self) -> List[dict]:
        return [dataclasses.asdict(e) for e in self.events]


def resume_plan(monitor: FleetMonitor, chips_per_host: int,
                model_axis: int) -> dict:
    """The full recovery decision: who to evict, what mesh to rebuild,
    whether training can continue."""
    failed = monitor.failed_hosts()
    strag = monitor.stragglers()
    monitor.evict(failed + strag)
    chips = monitor.healthy_count() * chips_per_host
    mesh = plan_elastic_mesh(chips, model_axis)
    return {
        "evicted_failed": failed,
        "evicted_stragglers": strag,
        "healthy_chips": chips,
        "mesh": mesh,
        "action": "continue" if mesh else "halt",
    }
