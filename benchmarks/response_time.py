"""Paper Table III: response time + memory, KOIOS vs Baseline/Baseline+.

Also covers the SilkMoth comparison mode (--sim ngram): the same engine
with character n-gram Jaccard similarity (KOIOS is similarity-agnostic —
§VIII-B)."""
from __future__ import annotations

import numpy as np

from repro.core import (NGramJaccardSimilarity, SearchParams,
                        baseline_plus_topk, baseline_topk, search_partition)
from repro.data import sample_queries

from .common import index_for, memory_footprint_bytes, timed, world


def _ngram_incidence(vocab_size: int, dim: int = 512, seed: int = 0):
    """Hashed 3-gram incidence stand-in (tokens are synthetic ids; we hash
    pseudo-spellings)."""
    rng = np.random.default_rng(seed)
    inc = np.zeros((vocab_size, dim), np.float32)
    for t in range(vocab_size):
        g = rng.integers(0, dim, size=6)      # ~6 3-grams per token
        inc[t, g] = 1.0
    return inc


def run(datasets=("dblp", "opendata", "twitter", "wdc"), n_queries=2,
        k=10, alpha=0.8, sim_kind="cosine", include_baseline=True):
    rows = []
    params = SearchParams(k=k, alpha=alpha)
    for ds in datasets:
        coll, sim = world(ds)
        if sim_kind == "ngram":
            sim = NGramJaccardSimilarity(_ngram_incidence(coll.vocab_size))
        index = index_for(ds)
        queries = sample_queries(coll, n_queries, seed=11)
        # warm the jit caches (the paper's timings exclude setup; pow2
        # padding makes later queries reuse these compilations)
        if queries:
            search_partition(index, queries[0], sim, params)
            if include_baseline:
                baseline_topk(index, queries[0], sim, params)
        tk = tb = tbp = 0.0
        match_k = match_b = 0
        for q in queries:
            rk, dt = timed(search_partition, index, q, sim, params)
            tk += dt
            match_k += rk.stats.exact_matches
            if include_baseline:
                rb, dt = timed(baseline_topk, index, q, sim, params)
                tb += dt
                match_b += rb.stats.exact_matches
                rbp, dt = timed(baseline_plus_topk, index, q, sim, params)
                tbp += dt
                # sanity: identical score multisets
                assert np.allclose(np.sort(rk.lb), np.sort(rb.lb), atol=1e-3)
        n = max(len(queries), 1)
        mem = memory_footprint_bytes(ds, int(np.mean(
            [len(q) for q in queries])) if queries else 1)
        rows.append({
            "dataset": ds, "sim": sim_kind, "queries": n,
            "koios_s": tk / n,
            "baseline_s": tb / n if include_baseline else None,
            "baseline_plus_s": tbp / n if include_baseline else None,
            "speedup": (tb / tk) if include_baseline and tk else None,
            "em_koios": match_k / n,
            "em_baseline": match_b / n if include_baseline else None,
            "mem_mb": mem["total"] / 1e6,
        })
    return rows


def main():
    print("dataset,sim,koios_s,baseline_s,baseline+_s,speedup,"
          "em_koios,em_baseline,mem_mb")
    for r in run():
        print(f"{r['dataset']},{r['sim']},{r['koios_s']:.2f},"
              f"{r['baseline_s']:.2f},{r['baseline_plus_s']:.2f},"
              f"{r['speedup']:.1f},{r['em_koios']:.0f},"
              f"{r['em_baseline']:.0f},{r['mem_mb']:.1f}")


if __name__ == "__main__":
    main()
