"""Sharded collection resource (DESIGN.md §5): the N-shard repository is
bit-identical to the 1-shard reference across shard counts x schedules x
verifiers, theta_lb stays monotone under the cross-shard bound exchange,
the ShardedCollection is the ONLY owner of collection device state
(upload-once, every consumer borrows), the device-side top-k merge tree
reproduces the stable host argsort on ties and signed zeros, placement
changes nothing, and the admission-router fleet cannot perturb any
result."""
import numpy as np
import pytest

from repro.core import (KoiosSearch, SearchParams, SearchResult, SearchStats,
                        merge_topk_batch, partition_ranges)
from repro.data import make_collection, make_embeddings, sample_queries
from repro.runtime import instrument
from repro.runtime.collection import Shard, ShardedCollection


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_caches():
    """Drop jax's accumulated executable caches before this module.

    This file compiles many fresh program variants (per-shard wave
    configs, merge trees, shard-local refinement shapes) on top of
    everything the ~250 preceding suite tests already JIT'd; on CPU
    jaxlib that accumulation has produced backend_compile segfaults
    at exactly this point in the full run (standalone the file is
    fine).  Clearing is semantically free — later tests recompile on
    demand — and keeps the suite's peak compiled-code footprint
    bounded."""
    import jax

    jax.clear_caches()


def _params(verifier="hungarian", fused=None):
    kw = dict(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
              verifier=verifier)
    if fused is not None:
        kw["fused"] = fused
    return SearchParams(**kw)


# ------------------------------------------------------- bitwise parity
@pytest.mark.parametrize("verifier", ["hungarian", "auction", "hybrid"])
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_matches_one_shard_bitwise(small_world, verifier, shards):
    """The tentpole guarantee: N contiguous-range shards return the same
    ids and the same lb/ub floats as the unsharded repository, under
    every schedule (sequential host loop, overlapped scheduler, fused
    on-device waves + the device-side top-k merge tree)."""
    coll, sim = small_world
    params = _params(verifier, fused="interpret")
    reference = KoiosSearch(None, sim, params,
                            collection=ShardedCollection.build(coll, 1))
    sharded = KoiosSearch(
        None, sim, params,
        collection=ShardedCollection.build(coll, shards))
    assert sharded.collection.num_shards == shards
    queries = sample_queries(coll, 4, seed=5)
    ref = reference.search_batch(queries, schedule="sequential")
    for schedule in ("sequential", "overlap", "fused"):
        got = sharded.search_batch(queries, schedule=schedule)
        if schedule == "fused":
            assert sharded.scheduler_stats.schedule == "fused"
        for a, b in zip(ref, got):
            assert np.array_equal(a.ids, b.ids), schedule
            assert np.array_equal(a.lb, b.lb), schedule   # bit-identical
            assert np.array_equal(a.ub, b.ub), schedule


def test_shard_ranges_cover_collection(small_world):
    """Shards are contiguous, non-empty, and tile [0, num_sets)."""
    coll, _ = small_world
    for n in (1, 3, 7):
        sc = ShardedCollection.build(coll, n)
        ranges = sc.shard_ranges()
        assert ranges[0][0] == 0 and ranges[-1][1] == coll.num_sets
        for (alo, ahi), (blo, bhi) in zip(ranges, ranges[1:]):
            assert ahi == blo and ahi > alo and bhi > blo
        for sid, s in enumerate(sc.shards):
            assert s.sid == sid
            assert s.coll.num_sets == ranges[sid][1] - ranges[sid][0]


# ------------------------------------------------- theta_lb monotonicity
@pytest.mark.parametrize("schedule", ["overlap", "fused"])
def test_theta_monotone_under_cross_shard_exchange(small_world, schedule):
    """The shared theta_lb bound is only ever raised as waves cross shard
    boundaries — every exchange point in the trace is >= its
    predecessor, so cross-shard pruning is certified."""
    coll, sim = small_world
    engine = KoiosSearch(
        None, sim, _params(fused="interpret"),
        collection=ShardedCollection.build(coll, 4))
    queries = sample_queries(coll, 3, seed=31)
    results = engine.search_batch(queries, schedule=schedule)
    trace = engine.scheduler_stats.theta_trace
    assert len(trace) >= 1
    for prev, cur in zip(trace, trace[1:]):
        assert np.all(cur >= prev - 1e-12), (prev, cur)
    for qi, res in enumerate(results):
        if len(res.lb) >= engine.params.k:
            assert trace[-1][qi] <= res.lb[engine.params.k - 1] + 1e-6


# ----------------------------------------------------- ownership/borrow
def test_collection_is_sole_owner_upload_once(small_world):
    """Device state lives on the resource, not on any consumer: two
    KoiosSearch instances sharing one ShardedCollection borrow the SAME
    cached per-shard arrays, and the CSR/operand/table uploads happen
    exactly once per shard no matter how many consumers search."""
    coll, sim = small_world
    sc = ShardedCollection.build(coll, 3)
    a = KoiosSearch(None, sim, _params(fused="interpret"), collection=sc)
    b = KoiosSearch(None, sim, _params(fused="interpret"), collection=sc)
    assert a.collection is sc and b.collection is sc
    assert a.partitions is sc.shards        # borrowed views, not copies
    queries = sample_queries(coll, 2, seed=9)

    with instrument.counting() as cold:
        a.search_batch(queries, schedule="fused")
    assert cold["h2d:index_upload"] == sc.num_shards     # one per shard
    with instrument.counting() as warm:
        b.search_batch(queries, schedule="fused")
        a.search_batch(queries, schedule="fused")
    assert warm["h2d:index_upload"] == 0     # second consumer re-borrows

    for s in sc.shards:                      # borrows are cached objects
        assert s.wave_operands() is s.wave_operands()
        assert s.csr_arrays() is not None
    assert sc.device_bytes() > 0
    desc = sc.describe()
    assert [d["sets"] for d in desc["shards"]] == \
        [s.coll.num_sets for s in sc.shards]


def test_adopt_preserves_shard_state(small_world):
    """ShardedCollection.adopt wraps prebuilt indexes without rebuilding
    or re-uploading: existing Shards keep identity (and device cache)."""
    coll, _ = small_world
    sc = ShardedCollection.build(coll, 2)
    ops = [s.wave_operands() for s in sc.shards]
    adopted = ShardedCollection.adopt(coll, sc.shards)
    assert adopted.shards[0] is sc.shards[0]
    for s, o in zip(adopted.shards, ops):
        assert s.wave_operands() is o


# ------------------------------------------------------------ placement
def test_placed_shards_bitwise_and_pinned(small_world):
    """Placement (shard i pinned to device i%D) changes no bit: the
    placed fused run equals the unplaced reference, each placed shard's
    arrays live on its device, and uploads happen once per shard."""
    import jax

    coll, sim = small_world
    devices = jax.devices()                 # >= 1 always; CI forces 8
    params = _params(fused="interpret")
    reference = KoiosSearch(None, sim, params,
                            collection=ShardedCollection.build(coll, 1))
    placed_sc = ShardedCollection.build(coll, 4, devices=devices)
    assert placed_sc.placed
    placed = KoiosSearch(None, sim, params, collection=placed_sc)
    queries = sample_queries(coll, 3, seed=5)

    with instrument.counting() as c:
        got = placed.search_batch(queries, schedule="fused")
    ref = reference.search_batch(queries, schedule="fused")
    for a, b in zip(ref, got):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.lb, b.lb)
        assert np.array_equal(a.ub, b.ub)
    for s in placed_sc.shards:
        assert s.device is devices[s.sid % len(devices)]
        assert c[f"h2d:index_upload[s{s.sid}]"] == 1
        assert c[f"h2d:operand_upload[s{s.sid}]"] == 1
        for arr in s.csr_arrays():
            assert arr.devices() == {s.device}
    if len(devices) > 1:                    # theta carry hopped devices
        assert any(k.startswith("h2d:theta_hop") for k in c)
    with instrument.counting() as warm:     # steady state: no re-upload
        placed.search_batch(queries, schedule="fused")
    assert not any(site in k for k in warm
                   for site in ("index_upload", "operand_upload",
                                "table_upload")), dict(warm)


# ------------------------------------------------------ merge tree order
def test_merge_tree_matches_stable_host_argsort():
    """Property: the device-side log-depth merge tree reproduces
    np.argsort(-lb, kind='stable')[:k] over the partition-order
    concatenation — including duplicate scores (partition order wins)
    and -0.0 vs +0.0 ties (no IEEE sign-split in the sort key)."""
    rng = np.random.default_rng(3)
    k = 4
    for trial in range(20):
        per_q = []
        for _ in range(rng.integers(1, 4)):
            parts = []
            for _ in range(rng.integers(1, 6)):
                n = int(rng.integers(0, k + 3))
                lb = rng.choice(
                    [1.0, 0.5, 0.5, 0.25, 0.0, -0.0]).astype(np.float32) \
                    * np.ones(n, np.float32) if n and trial % 3 == 0 else \
                    np.sort(rng.random(n).astype(np.float32))[::-1]
                lb = np.sort(lb)[::-1]      # partition lists arrive sorted
                parts.append(SearchResult(
                    ids=rng.integers(0, 1000, n).astype(np.int32),
                    lb=lb, ub=lb + np.float32(0.125),
                    stats=SearchStats(candidates=n)))
            per_q.append(parts)
        merged = merge_topk_batch(per_q, k)
        for rs, got in zip(per_q, merged):
            lb = np.concatenate([r.lb for r in rs] or [np.zeros(0)])
            ids = np.concatenate([r.ids for r in rs] or [np.zeros(0)])
            ub = np.concatenate([r.ub for r in rs] or [np.zeros(0)])
            order = np.argsort(-lb, kind="stable")[:k]
            assert np.array_equal(got.ids, ids[order])
            assert np.array_equal(got.lb, lb[order])
            assert np.array_equal(got.ub, ub[order])
            assert got.stats.candidates == sum(
                r.stats.candidates for r in rs)


# ------------------------------------------- partition_ranges degeneracy
def test_token_partition_ranges_never_empty():
    """Regression: the token-balanced splitter used to emit empty
    partitions when ``partitions`` approached the set count (greedy cuts
    collapse under size skew); every partition must hold >= 1 set."""
    skew = np.array([100, 1, 1, 1, 1])
    for p in (1, 2, 3, 4, 5, 6, 9):
        bounds = partition_ranges(skew, p, by="tokens")
        assert bounds[0] == 0 and bounds[-1] == len(skew)
        assert np.all(np.diff(bounds) > 0), (p, bounds)
        assert len(bounds) == min(p, len(skew)) + 1
    rng = np.random.default_rng(0)
    for _ in range(25):
        n = int(rng.integers(1, 12))
        sizes = rng.integers(1, 60, n)
        sizes[rng.integers(0, n)] = 500     # one dominating set
        p = int(rng.integers(1, n + 3))
        bounds = partition_ranges(sizes, p, by="tokens")
        assert bounds[0] == 0 and bounds[-1] == n
        assert np.all(np.diff(bounds) > 0), (sizes, p, bounds)


def test_build_drops_empty_shards():
    """shards > num_sets degenerates to one set per shard (by='sets'
    ranges past the end are dropped, never emitted empty)."""
    coll = make_collection(num_sets=3, vocab_size=50, avg_size=4,
                           max_size=8, seed=1)
    sc = ShardedCollection.build(coll, 8)
    assert sc.num_shards == 3
    assert all(s.coll.num_sets == 1 for s in sc.shards)


# -------------------------------------------------------- router parity
def test_admission_router_cannot_perturb_results(small_world):
    """The replica fleet behind the admission router returns, in global
    submission order, responses bit-identical to a one-shot
    search_batch over the same shared collection."""
    from repro.runtime.engine import AdmissionRouter

    coll, sim = small_world
    params = _params()
    sc = ShardedCollection.build(coll, 2)
    one_shot = KoiosSearch(None, sim, params, collection=sc)
    router = AdmissionRouter(None, sim, params, replicas=3, collection=sc)
    assert all(e.collection is sc for e in router.engines)

    queries = sample_queries(coll, 7, seed=13)
    ref = one_shot.search_batch(queries)
    responses = router.serve(queries)
    assert [r.rid for r in responses] == list(range(len(queries)))
    for r, a in zip(responses, ref):
        assert np.array_equal(r.result.ids, a.ids)
        assert np.array_equal(r.result.lb, a.lb)
    s = router.summary()
    assert s["replicas"] == 3
    assert s["requests"] == len(queries)
    assert sum(p["requests"] for p in s["per_replica"]) == len(queries)
    # least-pending + round-robin: an idle fleet spreads arrivals
    assert all(p["requests"] > 0 for p in s["per_replica"])
