"""KOIOS core — top-k semantic overlap set search (the paper's contribution).

Public API:
    SetCollection, SearchParams, SearchResult   (types)
    EmbeddingSimilarity, NGramJaccardSimilarity (similarity providers)
    KoiosSearch, KoiosIndex                     (search engine)
    baseline_topk, baseline_plus_topk, brute_force_topk (paper baselines)
"""
from .types import SetCollection, SearchParams, SearchResult, SearchStats
from .similarity import EmbeddingSimilarity, NGramJaccardSimilarity
from .inverted_index import InvertedIndex
from .token_stream import (build_token_stream, build_token_stream_batch,
                           expand_to_events)
from .scheduler import ExecutionPlan, SchedulerStats, run_plan
from .search import (KoiosSearch, KoiosIndex, partition_ranges,
                     search_partition, search_partition_batch, merge_topk)
from .baseline import baseline_topk, baseline_plus_topk, brute_force_topk

__all__ = [
    "SetCollection", "SearchParams", "SearchResult", "SearchStats",
    "EmbeddingSimilarity", "NGramJaccardSimilarity", "InvertedIndex",
    "build_token_stream", "build_token_stream_batch", "expand_to_events",
    "ExecutionPlan", "SchedulerStats", "run_plan",
    "KoiosSearch", "KoiosIndex", "partition_ranges", "search_partition",
    "search_partition_batch", "merge_topk",
    "baseline_topk", "baseline_plus_topk", "brute_force_topk",
]
