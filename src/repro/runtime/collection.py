"""Sharded collection resource: ONE logical repository, pod-scale placement,
epoch-versioned live updates, crash-consistent snapshots.

Every serving layer built before this module sharded *queries*; the
collection itself (CSR inverted index triplet, embedding table, set-norm
metadata) lived whole on one device inside each ``KoiosSearch``.  This
module makes the collection a first-class **resource object**:

:class:`ShardedCollection`
    Owns the repository split — contiguous set ranges over a shard axis
    (paper §VI; LES3 makes the same partition-level-index argument for
    exact set search at corpus scale) — and ALL of its device state.
    Built once, shared by every consumer: ``KoiosSearch`` instances, the
    request engine, engine replicas behind the admission router, and
    benchmarks all borrow the same per-shard operand views, so the CSR
    triplet / dense token matrix / normalized embedding table of a shard
    is uploaded exactly once per process, not once per consumer.

:class:`Shard`
    One contiguous set range: the partition-local :class:`SetCollection`,
    its inverted index, the global id offset, and an optional *placement
    device*.  The search/scheduler/wave layers receive Shards wherever
    they historically received ``KoiosIndex``es (``Shard`` IS a
    ``KoiosIndex`` — same host fields, so the host pipeline is oblivious)
    and **borrow** device operands through the accessors below instead of
    owning uploads:

      ``csr_arrays()``    int32 CSR triplet for in-trace event expansion
      ``wave_operands()`` dense (num_sets, c_pad) token matrix + sizes
      ``table_for(sim)``  the provider's row-normalized embedding table,
                          resident on the shard's device

Placement: ``ShardedCollection.build(..., devices=...)`` pins shard *i*'s
arrays to device *i* (``jax.device_put``); each shard's fused wave then
runs where its data lives, and the theta_lb carry hops device-to-device
between waves (the shared-bound exchange of DESIGN.md §5 — the same
``all_reduce_max`` contract, realised as carry chaining when waves are
driven from one host).  ``devices=None`` leaves every array uncommitted
on the default device — the single-device case is the degenerate 1-place
instance of the same code path, not a fork.

Live updates (DESIGN.md §6.5): the repository is no longer
process-lifetime-immutable.  A :class:`CollectionUpdate` transaction
(``begin_update() / add_sets() / remove_sets() / commit()``) produces a
new :class:`CollectionEpoch` by **copy-on-write over shards**: only
shards whose membership changed rebuild their local collection /
inverted index (and therefore their CSR / operand / table device state);
unchanged shards are re-wrapped sharing the same ``coll``/``inv`` and
the same cached device arrays by reference (a shard's device state
depends only on its LOCAL content, never on its global offset — offsets
are applied host-side when tiles finish).  Readers (``ExecutionPlan``s,
engines) ``pin()`` the epoch they were admitted under and stay bit-exact
against that consistent snapshot; ``release()`` of the last reader of a
non-head epoch drops the device state exclusive to it (the reader-drain
rule: an old epoch's buffers are only released after its readers drain).

Crash consistency: ``save()/restore()`` write per-shard payloads plus an
epoch manifest through the ``checkpoint/`` machinery with
write-temp-then-atomic-rename — a crash mid-commit restores either the
old or the new epoch, never a torn mix
(:class:`repro.checkpoint.collection.CollectionSnapshotter`).

Exactness is placement-, shard-count-, and epoch-invariant: shard
boundaries only change which tile a set's events land in, every per-set
numeric is computed from shard-local operands identical to the unsharded
slices, and the shared theta_lb bound is only ever raised (monotone,
certified) — so sharded top-k is bit-identical to the 1-shard reference
(tests/test_sharded_collection.py), and a pinned epoch's top-k is
bit-identical to a fresh build of that epoch's repository
(tests/test_collection_epoch.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.inverted_index import InvertedIndex
from ..core.search import KoiosIndex, partition_ranges
from ..core.types import SetCollection, assert_int32, pow2
from . import instrument


class UpdateValidationError(ValueError):
    """A live-update transaction carried invalid sets or set ids —
    raised at ``add_sets``/``remove_sets``/``commit`` time so a bad
    update can never corrupt the committed repository."""


@dataclasses.dataclass
class Shard(KoiosIndex):
    """One contiguous set range of the repository + its device residency.

    Host fields are exactly ``KoiosIndex`` (coll, inv, id_offset), so the
    scheduler's tiles and the host pipeline consume Shards unchanged.
    Device state is built lazily on first borrow and cached on the shard
    — the ShardedCollection (not any search object) is its owner, and its
    lifetime is the owning epoch's lifetime (reader-drain rule).
    """

    sid: int = 0                     # shard index within the collection
    device: Optional[Any] = None     # placement; None = default device

    def _put(self, x):
        """Upload ``x`` honoring the shard's placement."""
        import jax
        import jax.numpy as jnp

        if self.device is None:
            return jnp.asarray(x)
        return jax.device_put(x, self.device)

    # ------------------------------------------------------------ borrows
    def csr_arrays(self):
        """Device-resident int32 CSR triplet (indptr, posting_set,
        posting_slot) for the fused wave's in-trace event expansion
        (DESIGN.md §3.3) — uploaded once per shard lifetime.

        Unplaced shards delegate to ``InvertedIndex.device_arrays`` —
        ONE cache (and one ``h2d:index_upload`` record) shared with any
        direct index consumer; placed shards pin a committed copy."""
        if self.device is None:
            return self.inv.device_arrays()
        cached = self.__dict__.get("_csr")
        if cached is None:
            assert_int32(self.inv.total_postings, "total_postings")
            instrument.record(f"h2d:index_upload[s{self.sid}]")
            pad = np.zeros(1, np.int32)
            cached = tuple(self._put(a) for a in (
                self.inv.tok_indptr.astype(np.int32),
                np.concatenate(
                    [self.inv.posting_set.astype(np.int32), pad - 1]),
                np.concatenate(
                    [self.inv.posting_slot.astype(np.int32), pad])))
            self._csr = cached
        return cached

    def wave_operands(self):
        """Dense (num_sets, pow2(max set size)) token matrix + int32 set
        sizes + the pow2 column pad — the fused wave's verification
        operands, built and uploaded once per shard lifetime.

        On a size-skewed shard one outlier set inflates ``c_pad`` for
        every row — token-balanced sharding (``by='tokens'``) keeps
        shards uniform; at repository-shard scales the dense form is what
        keeps every round's weight gather one slice."""
        cached = self.__dict__.get("_wave_ops")
        if cached is None:
            coll = self.coll
            sizes = coll.set_sizes
            c_pad = pow2(int(sizes.max()) if len(sizes) else 1)
            dense = np.full((coll.num_sets, c_pad), -1, np.int32)
            if coll.total_tokens:
                rows = np.repeat(np.arange(coll.num_sets), sizes)
                cols = np.arange(coll.total_tokens) \
                    - np.repeat(coll.set_indptr[:-1], sizes)
                dense[rows, cols] = coll.set_tokens
            if self.device is not None:
                instrument.record(f"h2d:operand_upload[s{self.sid}]")
            cached = (self._put(dense), self._put(sizes.astype(np.int32)),
                      c_pad)
            self._wave_ops = cached
        return cached

    def table_for(self, sim_provider):
        """The provider's row-L2-normalized embedding table, resident on
        this shard's device.  Unplaced shards share the provider's own
        cached device table (one upload per provider, process-wide);
        placed shards keep one pinned copy per (provider, device)."""
        from ..core.similarity import normalized_table_for

        table = normalized_table_for(sim_provider)
        if self.device is None:
            return table
        cache = self.__dict__.setdefault("_tables", {})
        hit = cache.get(id(sim_provider))
        if hit is None:
            import jax

            instrument.record(f"h2d:table_upload[s{self.sid}]")
            # pin the provider so its id cannot be recycled while cached
            hit = cache[id(sim_provider)] = (
                jax.device_put(table, self.device), sim_provider)
        return hit[0]

    # ------------------------------------------------------ copy-on-write
    def share_as(self, id_offset: int, sid: int) -> "Shard":
        """A new Shard over the SAME local collection/index (and the same
        cached device arrays, by reference) at a possibly different
        global offset — the copy-on-write share of an unchanged shard
        across a commit.  Sound because every device operand is a pure
        function of the LOCAL collection: the global offset is added
        host-side when a tile's partition-local top-k is finished, so two
        epochs can disagree about a shard's offset while sharing every
        one of its buffers."""
        s = Shard(coll=self.coll, inv=self.inv,
                  id_offset=int(id_offset), sid=int(sid),
                  device=self.device)
        for k in ("_csr", "_wave_ops", "_tables"):
            if k in self.__dict__:
                s.__dict__[k] = self.__dict__[k]
        return s

    def drop_device_state(self) -> None:
        """Release this shard's cached device arrays (reader-drain of a
        retired epoch).  The JAX buffers free when the last Python
        reference dies — shards of live epochs sharing the same ``inv``
        keep theirs (the owner checks liveness before calling)."""
        self.__dict__.pop("_csr", None)
        self.__dict__.pop("_wave_ops", None)
        self.__dict__.pop("_tables", None)
        if self.inv is not None:
            self.inv.__dict__.pop("_device_arrays", None)


# --------------------------------------------------------------- helpers
def _coll_from_sets(token_sets: Sequence[np.ndarray],
                    vocab_size: int) -> SetCollection:
    """A CSR :class:`SetCollection` from a list of per-set token arrays."""
    sizes = np.asarray([len(t) for t in token_sets], np.int64)
    indptr = np.zeros(len(token_sets) + 1, np.int64)
    np.cumsum(sizes, out=indptr[1:])
    tokens = (np.concatenate([np.asarray(t, np.int32) for t in token_sets])
              if token_sets else np.zeros(0, np.int32))
    return SetCollection(set_indptr=indptr, set_tokens=tokens,
                         vocab_size=int(vocab_size))


def _concat_colls(colls: Sequence[SetCollection],
                  vocab_size: int) -> SetCollection:
    """Concatenate shard-local collections back into one repository."""
    indptr = [np.zeros(1, np.int64)]
    tokens = []
    base = 0
    for c in colls:
        indptr.append(c.set_indptr[1:] + base)
        tokens.append(c.set_tokens)
        base += c.total_tokens
    return SetCollection(
        set_indptr=np.concatenate(indptr),
        set_tokens=(np.concatenate(tokens) if tokens
                    else np.zeros(0, np.int32)),
        vocab_size=int(vocab_size))


@dataclasses.dataclass
class CollectionEpoch:
    """One immutable version of the repository: the global collection,
    its shard list, and a reader refcount.  Readers (engines, one-shot
    plans) ``pin()`` the epoch they execute against — their top-k is
    computed from this consistent snapshot bit-exactly, however many
    commits land while they run — and ``release()`` it when done; the
    last release of a non-head epoch drops its exclusive device state
    (the reader-drain rule, DESIGN.md §6.5)."""

    epoch: int
    coll: SetCollection
    shards: List[Shard]
    readers: int = 0


class CollectionUpdate:
    """One open live-update transaction against the head epoch.

    ``add_sets``/``remove_sets`` stage changes; ``commit`` builds the
    next :class:`CollectionEpoch` copy-on-write (only shards whose
    membership changed rebuild — additions append to the LAST shard,
    removals rebuild their owning shard; everything else is shared by
    reference) and installs it as head.  Ids in ``remove_sets`` are
    global set ids of the epoch the transaction was opened against; a
    commit defines the NEXT epoch's id space (contiguous CSR — removals
    shift later ids down, additions append at the end).  One transaction
    may be open at a time; ``abort()`` discards it."""

    def __init__(self, parent: "ShardedCollection"):
        self._parent = parent
        self._base = parent._head
        self._adds: List[np.ndarray] = []
        self._removes: "set[int]" = set()
        self._closed = False

    def _check_open(self) -> None:
        if self._closed:
            raise UpdateValidationError("update transaction already closed")
        if self._parent._head is not self._base:
            raise UpdateValidationError(
                "update transaction raced a commit (stale base epoch)")

    def add_sets(self, token_sets: Sequence[np.ndarray]) -> None:
        """Stage new sets (validated: 1-D, non-empty, distinct in-vocab
        tokens — sets, not bags, exactly like ``SetCollection``)."""
        self._check_open()
        vocab = self._base.coll.vocab_size
        for t in token_sets:
            a = np.asarray(t)
            if a.ndim != 1 or a.size == 0:
                raise UpdateValidationError(
                    f"added set must be a non-empty 1-D token array, "
                    f"got shape {a.shape}")
            if not np.issubdtype(a.dtype, np.integer):
                raise UpdateValidationError(
                    f"added set has non-integer dtype {a.dtype}")
            if a.min() < 0 or a.max() >= vocab:
                raise UpdateValidationError(
                    f"added set tokens outside [0, {vocab})")
            if len(np.unique(a)) != len(a):
                raise UpdateValidationError(
                    "added set contains duplicate tokens (sets, not bags)")
            self._adds.append(a.astype(np.int32).copy())

    def remove_sets(self, set_ids: Sequence[int]) -> None:
        """Stage removals by GLOBAL set id of the transaction's base
        epoch."""
        self._check_open()
        n = self._base.coll.num_sets
        for i in set_ids:
            i = int(i)
            if not 0 <= i < n:
                raise UpdateValidationError(
                    f"remove_sets id {i} outside [0, {n})")
            self._removes.add(i)

    def abort(self) -> None:
        self._closed = True
        if self._parent._update is self:
            self._parent._update = None

    def commit(self) -> int:
        """Build + install the next epoch; returns its epoch number.
        A no-op transaction (nothing staged) keeps the current epoch."""
        self._check_open()
        self._closed = True
        self._parent._update = None
        if not self._adds and not self._removes:
            return self._base.epoch

        head = self._base
        vocab = head.coll.vocab_size
        # removals grouped per owning shard (contiguous global ranges)
        rem_by_shard: Dict[int, List[int]] = {}
        for gid in self._removes:
            for si, s in enumerate(head.shards):
                lo = s.id_offset
                if lo <= gid < lo + s.coll.num_sets:
                    rem_by_shard.setdefault(si, []).append(gid - lo)
                    break
        last = len(head.shards) - 1
        new_shards: List[Shard] = []
        offset = shared = rebuilt = 0
        for si, s in enumerate(head.shards):
            local_rem = rem_by_shard.get(si, [])
            local_add = self._adds if si == last else []
            if not local_rem and not local_add:
                # membership unchanged: share coll/inv/device state by
                # reference; only the global offset may shift
                new_shards.append(s.share_as(offset, len(new_shards)))
                instrument.record(f"collection:shard_shared[s{s.sid}]")
                shared += 1
                offset += s.coll.num_sets
                continue
            keep = np.ones(s.coll.num_sets, bool)
            keep[np.asarray(local_rem, np.int64)] = False
            token_sets = [s.coll.get_set(i).copy()
                          for i in np.nonzero(keep)[0]] + local_add
            if not token_sets:
                continue                     # shard emptied out: dropped
            ncoll = _coll_from_sets(token_sets, vocab)
            new_shards.append(Shard(
                coll=ncoll, inv=InvertedIndex.build(ncoll),
                id_offset=offset, sid=len(new_shards), device=s.device))
            instrument.record(f"collection:shard_rebuilt[s{s.sid}]")
            rebuilt += 1
            offset += ncoll.num_sets
        if not new_shards:
            raise UpdateValidationError(
                "commit would empty the repository (every set removed)")
        new_coll = _concat_colls([s.coll for s in new_shards], vocab)
        ep = CollectionEpoch(epoch=head.epoch + 1, coll=new_coll,
                             shards=new_shards)
        self._parent._install(ep, shared=shared, rebuilt=rebuilt,
                              added=len(self._adds),
                              removed=len(self._removes))
        return ep.epoch


class ShardedCollection:
    """The repository as a shared, epoch-versioned resource: shards +
    their device state + the live-update/snapshot lifecycle.

    Consumers (``KoiosSearch``, ``RequestEngine``, engine replicas behind
    the :class:`~repro.runtime.engine.AdmissionRouter`) hold a reference
    and borrow operand views; none of them owns uploads.  Building the
    resource is host-only — device arrays materialize on first borrow.
    ``coll``/``shards`` always reflect the HEAD epoch; readers that need
    a consistent snapshot across steps ``pin()`` it (see
    :class:`CollectionEpoch`).
    """

    def __init__(self, coll: SetCollection, shards: Sequence[Shard],
                 epoch: int = 0):
        head = CollectionEpoch(epoch=int(epoch), coll=coll,
                               shards=list(shards))
        self._head = head
        self._retained: Dict[int, CollectionEpoch] = {head.epoch: head}
        self._update: Optional[CollectionUpdate] = None
        self._on_commit: List[Callable[["ShardedCollection"], None]] = []
        self._last_commit: Optional[dict] = None

    # --------------------------------------------------- head delegation
    @property
    def coll(self) -> SetCollection:
        return self._head.coll

    @property
    def shards(self) -> List[Shard]:
        return self._head.shards

    @property
    def epoch(self) -> int:
        return self._head.epoch

    @property
    def head(self) -> CollectionEpoch:
        return self._head

    # ---------------------------------------------------------- factories
    @staticmethod
    def build(coll: SetCollection, shards: int = 1, by: str = "sets",
              devices=None) -> "ShardedCollection":
        """Split ``coll`` into ``shards`` contiguous set ranges
        (``by='sets'`` equal counts / ``by='tokens'`` greedy token
        balance — :func:`repro.core.search.partition_ranges`) and wrap
        each in a :class:`Shard`.

        ``devices``: ``None`` keeps every shard on the default device
        (the degenerate single-place case); ``'auto'`` spreads shards
        round-robin over ``jax.devices()``; an explicit device sequence
        pins shard *i* to ``devices[i % len(devices)]``.  Empty ranges
        (``shards > num_sets``) are dropped, so every shard is
        non-empty."""
        if devices == "auto":
            import jax

            devices = jax.devices()
        bounds = partition_ranges(coll.set_sizes, shards, by=by)
        out: List[Shard] = []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            if hi <= lo:
                continue
            sid = len(out)
            dev = devices[sid % len(devices)] if devices else None
            out.append(Shard(
                coll=coll.slice_sets(int(lo), int(hi)),
                inv=None, id_offset=int(lo), sid=sid, device=dev))
        for s in out:
            s.inv = InvertedIndex.build(s.coll)
        return ShardedCollection(coll, out)

    @staticmethod
    def adopt(coll: SetCollection,
              indexes: Sequence[KoiosIndex]) -> "ShardedCollection":
        """Wrap prebuilt partition indexes (or existing Shards) as a
        collection resource — the compatibility entry for callers that
        built ``KoiosIndex``es directly.  Existing Shards keep their
        cached device state (and sid/placement)."""
        shards = [ix if isinstance(ix, Shard)
                  else Shard(coll=ix.coll, inv=ix.inv,
                             id_offset=ix.id_offset, sid=sid)
                  for sid, ix in enumerate(indexes)]
        return ShardedCollection(coll, shards)

    # --------------------------------------------------- epoch lifecycle
    def pin(self) -> CollectionEpoch:
        """Take a reader reference on the head epoch.  The returned
        epoch's ``coll``/``shards`` stay valid (device state retained)
        until the matching :meth:`release` — however many commits land
        meanwhile."""
        self._head.readers += 1
        return self._head

    def release(self, ep: CollectionEpoch) -> None:
        """Drop a reader reference.  The LAST reader of a retired
        (non-head) epoch releases the device state exclusive to it —
        never state shared with a live epoch (COW shards keep their
        buffers through the epochs that still reference them)."""
        ep.readers = max(ep.readers - 1, 0)
        if ep.readers == 0 and ep is not self._head:
            self._retained.pop(ep.epoch, None)
            self._release_device_state(ep)

    def _release_device_state(self, ep: CollectionEpoch) -> None:
        live = {id(s.inv) for e in self._retained.values()
                for s in e.shards}
        for s in ep.shards:
            if id(s.inv) in live:
                continue
            s.drop_device_state()
            instrument.record(f"collection:epoch_release[s{s.sid}]")

    def begin_update(self) -> CollectionUpdate:
        """Open the (single) live-update transaction against the head
        epoch."""
        if self._update is not None:
            raise UpdateValidationError(
                "an update transaction is already open")
        self._update = CollectionUpdate(self)
        return self._update

    def on_commit(self,
                  callback: Callable[["ShardedCollection"], None]) -> None:
        """Register a post-commit hook (fired after the new epoch is
        installed as head — ``serve.py --snapshot-dir`` snapshots here)."""
        self._on_commit.append(callback)

    def _install(self, ep: CollectionEpoch, shared: int, rebuilt: int,
                 added: int, removed: int) -> None:
        old = self._head
        self._retained[ep.epoch] = ep
        self._head = ep
        self._last_commit = {"epoch": ep.epoch, "shards_shared": shared,
                             "shards_rebuilt": rebuilt,
                             "sets_added": added, "sets_removed": removed}
        instrument.record("collection:commit")
        if old.readers == 0:
            self._retained.pop(old.epoch, None)
            self._release_device_state(old)
        for cb in self._on_commit:
            cb(self)

    # ------------------------------------------------- crash consistency
    def save(self, directory: str) -> dict:
        """Snapshot the HEAD epoch into ``directory`` (per-shard payloads
        + atomic epoch manifest: old-or-new, never torn).  Returns the
        manifest written."""
        from ..checkpoint.collection import CollectionSnapshotter

        return CollectionSnapshotter(directory).save(self)

    @staticmethod
    def restore(directory: str,
                devices=None) -> "Optional[ShardedCollection]":
        """Rebuild the collection (same shard split, same epoch number)
        from the latest manifest in ``directory``; ``None`` when no
        snapshot exists.  ``devices`` re-places shards exactly as
        :meth:`build` would (placement is host policy, not snapshot
        state)."""
        from ..checkpoint.collection import CollectionSnapshotter

        return CollectionSnapshotter(directory).restore(devices=devices)

    # ----------------------------------------------------------- geometry
    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def placed(self) -> bool:
        """Whether any shard is pinned to an explicit device."""
        return any(s.device is not None for s in self.shards)

    def shard_ranges(self) -> List[tuple]:
        """[(lo, hi)) global set-id range per shard."""
        return [(s.id_offset, s.id_offset + s.coll.num_sets)
                for s in self.shards]

    def device_bytes(self) -> int:
        """Host-side estimate of the per-shard device footprint already
        materialized (CSR triplets + dense operand matrices), over every
        RETAINED epoch's distinct shards (COW shares count once)."""
        total = 0
        seen = set()
        for e in self._retained.values():
            for s in e.shards:
                if id(s.inv) in seen:
                    continue
                seen.add(id(s.inv))
                if s.__dict__.get("_csr") is not None:
                    total += (4 * (s.inv.vocab_size + 1)
                              + 2 * 4 * (s.inv.total_postings + 1))
                ops = s.__dict__.get("_wave_ops")
                if ops is not None:
                    total += 4 * s.coll.num_sets * (ops[2] + 1)
        return total

    def describe(self) -> dict:
        """Placement/footprint/epoch summary (serving observability)."""
        return {
            "num_sets": self.coll.num_sets,
            "epoch": self.epoch,
            "retained_epochs": sorted(self._retained),
            "pinned_readers": {e: ep.readers
                               for e, ep in sorted(self._retained.items())
                               if ep.readers},
            "last_commit": self._last_commit,
            "shards": [
                {"sid": s.sid, "sets": s.coll.num_sets,
                 "tokens": s.coll.total_tokens,
                 "device": str(s.device) if s.device is not None else None}
                for s in self.shards],
            "device_bytes": self.device_bytes(),
        }
