from .sharding import (param_pspecs, opt_state_pspecs, input_pspecs,
                       to_shardings, fsdp_axes, dp_axes)
from .fault import (FleetMonitor, FaultConfig, plan_elastic_mesh,
                    resume_plan)

__all__ = ["param_pspecs", "opt_state_pspecs", "input_pspecs",
           "to_shardings", "fsdp_axes", "dp_axes", "FleetMonitor",
           "FaultConfig", "plan_elastic_mesh", "resume_plan"]
