"""Continuous-batching request engine (DESIGN.md §3.2).

The serving runtime the ROADMAP's "heavy traffic" north star asks for:
instead of the batch-synchronous demo loop (pre-form a batch, rebuild
streams and a plan from scratch, run it to completion, report one
amortized latency), :class:`RequestEngine` owns an explicit request
lifecycle

    admit -> stream -> plan -> waves -> postprocess -> respond

with cross-request reuse at every stage:

* **admit** — requests enter an admission queue with optional deadlines
  (earliest-deadline-first, FIFO among equals).  Nothing waits for a
  batch to "fill": every engine step coalesces whatever has arrived.
* **stream** — token streams come from an LRU
  :class:`~repro.core.token_stream.TokenStreamCache` keyed by
  (query tokens, alpha, provider): repeated or overlapping queries skip
  ``build_token_stream_batch`` entirely; the misses of a step build in
  ONE stacked sweep.
* **plan** — one long-lived :class:`~repro.core.scheduler.ExecutionPlan`
  absorbs joiners mid-flight (``plan.add_queries``): a request admitted
  while others are halfway through their partitions joins the very next
  wave.  Sound because a query's tiles read only its own theta carry and
  row-level numerics are schedule-invariant (DESIGN.md §3) — the final
  top-k is bit-identical to the one-shot ``search_batch`` path.
* **waves** — each step runs one wave: a tile per live request, each at
  its own next partition (``scheduler.run_wave``), or per-partition
  fused device programs (``scheduler.run_fused_wave``) through the
  engine-lifetime :func:`~repro.core.wave.wave_runner_for` runner.
  Batch shapes pad to the existing pow2 buckets, so steady-state serving
  triggers zero recompiles (tests/test_recompile.py).
* **respond** — per-request merge + true admit->respond latency from
  :class:`~repro.runtime.instrument.EngineCounters` (never an amortized
  batch figure).

The engine is single-threaded and synchronous — "continuous batching"
is a property of the schedule (mid-flight joins at wave boundaries), not
of host threading, exactly as in serving systems whose step loop owns
the batch (the vLLM lesson applied to set search).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.postprocess import VerifierPool
from ..core.scheduler import (ExecutionPlan, SchedulerStats, _exchange,
                              run_fused_wave, run_wave)
from ..core.search import KoiosIndex, merge_topk
from ..core.token_stream import (TokenStreamCache,
                                 build_token_stream_batch_cached)
from ..core.types import SearchParams, SearchResult
from .instrument import EngineCounters, RequestTrace


@dataclasses.dataclass
class _Request:
    """Engine-internal lifecycle record of one admitted request."""

    rid: int
    query: np.ndarray
    trace: RequestTrace
    arrival: float                       # visibility time (trace replay)
    seq: int                             # admission tiebreak (FIFO)
    qi: int = -1                         # plan query index once joined
    pending: List[int] = dataclasses.field(default_factory=list)
    parts: Dict[int, SearchResult] = dataclasses.field(default_factory=dict)

    def priority(self) -> tuple:
        d = self.trace.deadline
        return (d if d is not None else float("inf"), self.seq)


@dataclasses.dataclass(frozen=True)
class EngineResponse:
    """What ``respond`` emits: the merged result + true per-request
    lifecycle timings (the numbers ``serve_batch`` used to fake with one
    amortized figure)."""

    rid: int
    result: SearchResult
    latency_s: float                     # admit -> respond
    queue_s: float                       # admit -> first wave
    waves: int
    stream_hit: bool
    deadline_met: Optional[bool]


class RequestEngine:
    """Admission-queued, stream-cached, shape-bucketed search runtime.

    ``schedule``: ``"wave"`` drives host waves (works on any backend;
    ``"overlap"``/``"sequential"`` are accepted aliases — at wave
    granularity they coincide), ``"fused"`` runs each wave's
    per-partition groups as fused device programs where available
    (``core.wave.fused_available``; falls back to host waves).  Results
    are bit-identical across all of them and to the one-shot
    ``KoiosSearch.search_batch`` (tests/test_engine.py).

    ``clock``/``sleep`` are injectable for deterministic trace-replay
    tests; real serving uses the monotonic wall clock.

    Collection state lives in a :class:`ShardedCollection` resource —
    pass ``collection=`` to serve an existing (possibly placed, possibly
    shared-with-other-replicas) resource, or let the constructor build a
    private one from ``coll``/``partitions``/``partition_by``
    (``indexes=`` adopts prebuilt partition indexes into a resource —
    benchmarks sharing one index build).  The engine borrows per-shard
    operand views; it owns no collection device arrays.
    """

    def __init__(self, coll, sim_provider,
                 params: Optional[SearchParams] = None,
                 partitions: int = 1, schedule: str = "wave",
                 partition_by: str = "sets",
                 bound_exchange: Optional[Callable] = None, mesh=None,
                 stream_cache_capacity: int = 512,
                 max_wave_requests: int = 64,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 indexes: Optional[Sequence[KoiosIndex]] = None,
                 collection=None):
        from .collection import ShardedCollection

        self.params = params or SearchParams()
        self.sim = sim_provider
        if collection is None:
            collection = (ShardedCollection.adopt(coll, indexes)
                          if indexes is not None else
                          ShardedCollection.build(coll, partitions,
                                                  by=partition_by))
        self.collection = collection
        self.coll = collection.coll
        self.bound_exchange = bound_exchange
        self.mesh = mesh
        self.clock = clock
        self._sleep = sleep
        self.max_wave_requests = int(max_wave_requests)
        self.partitions = collection.shards

        if schedule in ("overlap", "sequential"):
            schedule = "wave"
        assert schedule in ("wave", "fused"), schedule
        self._runner = None
        if schedule == "fused":
            from ..core.wave import fused_available, wave_runner_for
            if fused_available(self.params, sim_provider):
                self._runner = wave_runner_for(sim_provider, self.params,
                                               mesh=mesh)
            else:
                schedule = "wave"
        self.schedule = schedule

        # engine-lifetime shared machinery (the cross-request reuse)
        self.plan = ExecutionPlan(self.partitions, [], pool_coll=self.coll)
        self.pool = VerifierPool(self.coll, sim_provider, self.params)
        self.stream_cache = TokenStreamCache(stream_cache_capacity)
        self.counters = EngineCounters()

        self._streams: List[object] = []          # aligned with plan.queries
        self._theta: List[float] = []             # per-query carry
        self._tiles: Dict[int, Dict[int, object]] = {}   # qi -> pi -> tile
        self._rid = itertools.count()
        self._seq = itertools.count()
        self._arrivals: List[_Request] = []       # future visibility
        self._queue: List[_Request] = []          # admitted, awaiting join
        self._inflight: Dict[int, _Request] = {}  # rid -> joined request
        self._completed: List[EngineResponse] = []

    # ------------------------------------------------------------- admit
    def submit(self, query, deadline: Optional[float] = None,
               arrival: Optional[float] = None) -> int:
        """Admit one request; returns its request id.

        ``deadline`` (clock timestamp) orders the admission queue
        (earliest first) and is reported as met/missed on respond.
        ``arrival`` defers the request's *visibility* to the engine —
        trace replay for staggered-arrival benchmarks; the admit
        timestamp is the arrival time, so queue time is measured from
        when the request actually arrived."""
        rid = next(self._rid)
        now = self.clock()
        t_arr = now if arrival is None else float(arrival)
        req = _Request(
            rid=rid, query=np.asarray(query, np.int32),
            trace=RequestTrace(rid=rid, t_admit=t_arr, deadline=deadline),
            arrival=t_arr, seq=next(self._seq))
        if t_arr > now:
            self._arrivals.append(req)
            self._arrivals.sort(key=lambda r: (r.arrival, r.seq))
        else:
            self._queue.append(req)
        return rid

    def _admit_arrived(self, now: float) -> None:
        while self._arrivals and self._arrivals[0].arrival <= now:
            self._queue.append(self._arrivals.pop(0))

    # -------------------------------------------------------------- join
    def _join(self, now: float) -> None:
        """Coalesce queued requests into the in-flight cohort: fetch or
        build their streams (one stacked sweep for all of a step's
        misses) and absorb them into the plan mid-flight."""
        room = self.max_wave_requests - len(self._inflight)
        if room <= 0 or not self._queue:
            return
        self._queue.sort(key=_Request.priority)
        joiners, self._queue = self._queue[:room], self._queue[room:]
        queries = [r.query for r in joiners]
        # per-request hit attribution: a duplicate of a query earlier in
        # the same join is served without a sweep too (matches the cache
        # counters' accounting of duplicate misses)
        hits, seen = [], set()
        for q in queries:
            key = self.stream_cache.key(q, self.params.alpha, self.sim)
            hits.append(self.stream_cache.contains(key) or key in seen)
            seen.add(key)
        streams = build_token_stream_batch_cached(
            queries, self.sim, self.params.alpha, self.stream_cache,
            use_kernel=self.params.stream_use_kernel)
        t_stream = self.clock()
        qis, new_tiles = self.plan.add_queries(queries)
        for t in new_tiles:
            self._tiles.setdefault(t.qi, {})[t.pi] = t
        self._streams.extend(streams)
        self._theta.extend([0.0] * len(joiners))
        for req, qi, hit in zip(joiners, qis, hits):
            req.qi = qi
            req.pending = list(range(len(self.partitions)))
            req.trace.t_stream = t_stream
            req.trace.stream_hit = bool(hit)
            self._inflight[req.rid] = req

    # -------------------------------------------------------------- waves
    def _run_wave_tiles(self, tiles) -> None:
        if self._runner is not None:
            by_pi: Dict[int, list] = {}
            for t in tiles:
                by_pi.setdefault(t.pi, []).append(t)
            for pi in sorted(by_pi):
                run_fused_wave(self.plan, by_pi[pi], self._streams,
                               self._theta, self.pool, self.params,
                               self._runner)
        else:
            run_wave(self.plan, tiles, self._streams, self._theta,
                     self.pool, self.params)
        if self.bound_exchange is not None and self._inflight:
            # fold the mesh's all-reduce-max back into the live carries
            qis = [r.qi for r in self._inflight.values()]
            vec = _exchange(np.asarray([self._theta[qi] for qi in qis],
                                       np.float64), self.bound_exchange)
            for qi, v in zip(qis, vec):
                self._theta[qi] = max(self._theta[qi], float(v))

    def step(self) -> List[EngineResponse]:
        """One continuous-batching step: admit arrivals, join the queue,
        run one wave (a tile per live request at its next partition),
        respond to whoever finished.  Returns the step's responses."""
        now = self.clock()
        self._admit_arrived(now)
        depth = len(self._queue)
        self._join(now)
        if not self._inflight:
            out, self._completed = self._completed, []
            return out

        wave, reqs = [], []
        for req in sorted(self._inflight.values(), key=_Request.priority):
            pi = req.pending.pop(0)
            tile = self._tiles[req.qi][pi]
            if req.trace.waves == 0:
                req.trace.t_first_wave = now
            req.trace.waves += 1
            wave.append(tile)
            reqs.append((req, pi))
        self.counters.observe_step(queue_depth=depth, wave_size=len(wave))
        self._run_wave_tiles(wave)

        t_done = self.clock()
        for req, pi in reqs:
            req.parts[pi] = self._tiles[req.qi][pi].result
            if not req.pending:
                self._respond(req, t_done)
        out, self._completed = self._completed, []
        return out

    # ------------------------------------------------------------ respond
    def _respond(self, req: _Request, t_done: float) -> None:
        result = merge_topk([req.parts[pi] for pi in sorted(req.parts)],
                            self.params.k)
        req.trace.t_respond = t_done
        self.counters.observe_respond(req.trace)
        self._completed.append(EngineResponse(
            rid=req.rid, result=result,
            latency_s=req.trace.latency_s, queue_s=req.trace.queue_s,
            waves=req.trace.waves, stream_hit=req.trace.stream_hit,
            deadline_met=req.trace.deadline_met))
        del self._inflight[req.rid]
        del self._tiles[req.qi]
        self._streams[req.qi] = None      # the LRU cache keeps the stream
        self._theta[req.qi] = 0.0
        remap = self.plan.retire_tiles([req.qi])
        if remap is not None:
            # the plan compacted its query ring (bounded plan size for
            # long-lived engines, DESIGN.md §8 item 9): shift every
            # qi-indexed engine structure through the same remap
            order = sorted(remap)        # old qis ascending == new order
            self._streams = [self._streams[old] for old in order]
            self._theta = [self._theta[old] for old in order]
            self._tiles = {remap[old]: tiles
                           for old, tiles in self._tiles.items()}
            for r in self._inflight.values():
                r.qi = remap[r.qi]

    # ------------------------------------------------------------- warmup
    def warmup(self, sample: Sequence[np.ndarray],
               reset_counters: bool = True) -> None:
        """Compile-warm the serving path before taking traffic.

        Serves pow2-sized cohorts of ``sample`` (stream sweep,
        refinement scan, solver, and wave shapes for every batch bucket
        the trace can coalesce), sweeps the SHARD-LOCAL fused wave-config
        grid (every shard x cohort bucket x the sample's pow2 event-chunk
        buckets plus a 2x guard bucket — steady-state queries landing one
        bucket above the sample still hit a compiled program), and sweeps
        the fused-verification pairwise pow2 grid, so steady-state
        serving — sharded or not — triggers zero recompiles
        (tests/test_recompile.py).  Standard request-engine startup
        practice; ``reset_counters`` wipes the warmup's traces from the
        metrics (the stream cache keeps its entries — that is warmup
        working as intended)."""
        sample = [np.asarray(q, np.int32) for q in sample]
        if sample:
            bs = 1
            while True:
                self.serve(sample[:bs])
                if bs >= len(sample):
                    break
                bs = min(2 * bs, len(sample))
            self._warmup_wave_grid(sample)
        # verification weight dispatch: the fused pairwise shape is
        # (pow2 rows, pow2 cols) — sweep the grid the pool can emit
        from ..core.postprocess import _pad_pow2
        q_hi = _pad_pow2(max((sum(len(q) for q in sample), 32)), 32)
        c_hi = min(VerifierPool._FUSE_TOKEN_CAP,
                   _pad_pow2(self.params.verify_batch
                             * max(int(self.coll.set_sizes.max()), 1)
                             * max(len(sample), 1), 256))
        qb = 32
        while qb <= q_hi:
            cb = 256
            while cb <= c_hi:
                self.sim.pairwise(np.zeros(qb, np.int32),
                                  np.zeros(cb, np.int32))
                cb *= 2
            qb *= 2
        if reset_counters:
            self.counters = EngineCounters()
            # scheduler-side counters (waves/rounds/...) are warmup work
            # too — reset them so summary() reflects only real traffic
            self.plan.stats = SchedulerStats(tiles=len(self.plan.tiles))

    def _warmup_wave_grid(self, sample: Sequence[np.ndarray]) -> None:
        """Sweep the shard-local fused wave-config grid (DESIGN.md §3.2).

        The serve() cohort sweep above compiles exactly the (shard,
        cohort-bucket, event-chunk-bucket) configs the SAMPLE's streams
        produce; live traffic with slightly heavier streams lands one
        pow2 chunk bucket up and would recompile mid-serve.  This pass
        walks the same doubling cohorts and, per shard, compiles the
        observed chunk bucket (an lru hit — free) plus its 2x guard
        bucket on an empty cohort (``WaveRunner.warm``), so every shard's
        near-neighborhood of the sample grid is compiled before traffic.
        Host-wave engines have no wave programs — nothing to do."""
        if self._runner is None:
            return
        from ..core.types import pow2
        from ..core.wave import _WAVE_CHUNK_GUARD
        streams = build_token_stream_batch_cached(
            sample, self.sim, self.params.alpha, self.stream_cache,
            use_kernel=self.params.stream_use_kernel)
        chunk = self.params.chunk_size
        counts = [s.inv.posting_counts() for s in self.partitions]
        bs = 1
        while True:
            cohort_q, cohort_s = sample[:bs], streams[:bs]
            B_pad = pow2(len(cohort_q))
            t_pad = pow2(max([len(s) for s in cohort_s] or [1]) or 1)
            nq_max = max(len(q) for q in cohort_q)
            nq_pad = pow2(max(nq_max, 1))
            q_words = pow2(max(1, -(-nq_max // 32)))
            for shard, cnt in zip(self.partitions, counts):
                buckets = set()
                for s in cohort_s:
                    n_events = int(cnt[s.token].sum())
                    if n_events:
                        buckets.add(pow2(max(1, -(-n_events // chunk))))
                for nc in sorted(b * g for b in buckets
                                 for g in _WAVE_CHUNK_GUARD):
                    self._runner.warm(shard, B_pad, nc, t_pad,
                                      nq_pad, q_words)
            if bs >= len(sample):
                break
            bs = min(2 * bs, len(sample))

    # -------------------------------------------------------------- drive
    def pending(self) -> int:
        """Requests anywhere in the lifecycle short of respond."""
        return len(self._arrivals) + len(self._queue) + len(self._inflight)

    def drain(self, max_idle_wait_s: float = 0.01) -> List[EngineResponse]:
        """Step until every submitted request (including future-dated
        arrivals) has responded; idle gaps sleep until the next arrival."""
        out: List[EngineResponse] = []
        while self.pending():
            out.extend(self.step())
            if not self._inflight and not self._queue and self._arrivals:
                wait = self._arrivals[0].arrival - self.clock()
                if wait > 0:
                    self._sleep(min(wait, max_idle_wait_s))
        out.extend(self.step())           # flush any buffered responses
        return out

    def serve(self, queries: Sequence[np.ndarray],
              deadlines: Optional[Sequence[Optional[float]]] = None
              ) -> List[EngineResponse]:
        """Submit a batch and drain it; responses in request-id order."""
        for i, q in enumerate(queries):
            self.submit(q, deadline=deadlines[i] if deadlines else None)
        return sorted(self.drain(), key=lambda r: r.rid)

    def summary(self) -> dict:
        """Engine metrics incl. stream-cache and scheduler stats."""
        out = self.counters.summary(cache_stats=self.stream_cache.stats())
        out["schedule"] = self.schedule
        out["scheduler"] = {
            "waves": self.plan.stats.waves,
            "rounds": self.plan.stats.rounds,
            "device_rounds": self.plan.stats.device_rounds,
            "fused_requests": self.plan.stats.fused_requests,
        }
        return out


class AdmissionRouter:
    """N :class:`RequestEngine` replicas over ONE logical collection
    behind a single front door (DESIGN.md §5).

    Every replica serves the SAME :class:`ShardedCollection` resource —
    per-shard device operands are uploaded once and borrowed by all, and
    identical (provider, params, mesh) triples share compiled wave
    programs through ``wave_runner_for`` — so a replica costs one plan +
    one verifier pool + one stream cache, not another copy of the
    repository.  The router admits requests with a global request id,
    routes each to the least-loaded replica (fewest lifecycle-pending
    requests; round-robin among ties, so an idle fleet still spreads
    arrivals), and merges responses back into global-rid order.  Replica
    count scales the host-side serving loop (admission, stream sweeps,
    postprocess continuation) over one repository; exactness is per
    replica — every response is bit-identical to a one-shot
    ``KoiosSearch.search_batch`` over the same collection, so routing
    cannot perturb any result (tests/test_sharded_collection.py)."""

    def __init__(self, coll, sim_provider,
                 params: Optional[SearchParams] = None, replicas: int = 2,
                 partitions: int = 1, partition_by: str = "sets",
                 collection=None, **engine_kwargs):
        from .collection import ShardedCollection

        assert replicas >= 1, replicas
        if collection is None:
            collection = ShardedCollection.build(coll, partitions,
                                                 by=partition_by)
        self.collection = collection
        self.engines = [
            RequestEngine(None, sim_provider, params,
                          collection=collection, **engine_kwargs)
            for _ in range(replicas)]
        self.clock = self.engines[0].clock       # shared trace clock
        self._rid = itertools.count()
        self._local: Dict[int, "tuple[int, int]"] = {}  # gid -> (eng, rid)
        self._gid: Dict["tuple[int, int]", int] = {}    # inverse
        self._rr = itertools.count()                    # tie-break cursor

    # ------------------------------------------------------------- routing
    def route(self) -> int:
        """Replica index for the next admit: least pending, round-robin
        among ties (deterministic under the injectable clocks)."""
        loads = [e.pending() for e in self.engines]
        lo = min(loads)
        ties = [i for i, n in enumerate(loads) if n == lo]
        return ties[next(self._rr) % len(ties)]

    def submit(self, query, deadline: Optional[float] = None,
               arrival: Optional[float] = None) -> int:
        """Admit one request to the fleet; returns its GLOBAL rid."""
        ei = self.route()
        rid = self.engines[ei].submit(query, deadline=deadline,
                                      arrival=arrival)
        gid = next(self._rid)
        self._local[gid] = (ei, rid)
        self._gid[(ei, rid)] = gid
        return gid

    def _globalize(self, ei: int,
                   responses: List[EngineResponse]
                   ) -> List[EngineResponse]:
        out = []
        for r in responses:
            gid = self._gid.pop((ei, r.rid))
            del self._local[gid]
            out.append(dataclasses.replace(r, rid=gid))
        return out

    # --------------------------------------------------------------- drive
    def pending(self) -> int:
        return sum(e.pending() for e in self.engines)

    def step(self) -> List[EngineResponse]:
        """One fleet step: every replica with work steps once (its own
        continuous-batching wave); responses come back with global rids."""
        out: List[EngineResponse] = []
        for ei, eng in enumerate(self.engines):
            if eng.pending():
                out.extend(self._globalize(ei, eng.step()))
        return out

    def drain(self) -> List[EngineResponse]:
        out: List[EngineResponse] = []
        while self.pending():
            out.extend(self.step())
        for ei, eng in enumerate(self.engines):     # flush buffered
            out.extend(self._globalize(ei, eng.step()))
        return out

    def serve(self, queries: Sequence[np.ndarray],
              deadlines: Optional[Sequence[Optional[float]]] = None
              ) -> List[EngineResponse]:
        """Submit a batch across the fleet and drain it; responses in
        global request-id (= submission) order."""
        for i, q in enumerate(queries):
            self.submit(q, deadline=deadlines[i] if deadlines else None)
        return sorted(self.drain(), key=lambda r: r.rid)

    def warmup(self, sample: Sequence[np.ndarray],
               reset_counters: bool = True) -> None:
        """Warm every replica.  Compiled programs (waves, scans, solvers)
        are process-global, so replica 0 pays the compiles and the rest
        sweep compile-free — but each replica still primes its own
        stream cache and shape buckets."""
        for eng in self.engines:
            eng.warmup(sample, reset_counters=reset_counters)

    def summary(self) -> dict:
        """Fleet metrics: per-replica summaries + fleet totals."""
        per = [e.summary() for e in self.engines]
        return {
            "replicas": len(self.engines),
            "collection": self.collection.describe(),
            "requests": sum(p["requests"] for p in per),
            "waves": sum(p["scheduler"]["waves"] for p in per),
            "per_replica": per,
        }
