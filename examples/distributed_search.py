"""Partitioned / distributed search (paper §VI scale-out).

Shows the shared-theta_lb mechanism: partitions searched later inherit the
bound from earlier ones (on a device mesh this is the all-reduce-max; the
host reference path shares the running max), which prunes their candidates
harder.  Compares 1 vs 4 partitions: identical results, and the stats show
the bound sharing at work.

    PYTHONPATH=src python examples/distributed_search.py
"""
import numpy as np

from repro.core import (EmbeddingSimilarity, KoiosSearch, SearchParams)
from repro.data import dataset_preset, make_embeddings, sample_queries

coll = dataset_preset("opendata", scale=0.02, seed=0)
emb = make_embeddings(coll.vocab_size, dim=32, seed=0)
sim = EmbeddingSimilarity(emb)
params = SearchParams(k=10, alpha=0.8)
q = sample_queries(coll, 1, seed=5)[0]

print(f"corpus: {coll.num_sets} sets, vocab {coll.vocab_size}, "
      f"|Q|={len(q)}")

for parts in (1, 4):
    engine = KoiosSearch(coll, sim, params, partitions=parts)
    res = engine.search(q)
    st = res.stats
    print(f"\npartitions={parts}: top-3 scores="
          f"{[round(float(s),2) for s in res.lb[:3]]}")
    print(f"  candidates={st.candidates} pruned={st.pruned_refinement} "
          f"verified={st.exact_matches} "
          f"(theta_lb shared across partitions prunes later shards harder)")

print("\nresult equality across partitionings is asserted in "
      "tests/test_search.py::test_partitions_share_theta; on a TPU mesh "
      "the shared bound is an all-reduce-max over the (pod, data) axes "
      "(DESIGN.md §5).")
