"""Mesh construction.  Everything here is a FUNCTION — importing this
module never touches jax device state (the dry-run must set XLA_FLAGS
before the first device query)."""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The production mesh: one v5e pod (16, 16) = ("data", "model"), or
    two pods (2, 16, 16) = ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Mesh over the first prod(shape) available devices (the dry-run's
    512 host devices serve both the 256- and 512-chip meshes)."""
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def single_device_mesh():
    """(1, 1) mesh for smoke/CPU runs — same code path as production."""
    return make_mesh((1, 1), ("data", "model"))


def bound_exchange_mesh(max_shards: int | None = None):
    """("data",)-axis mesh for the search scheduler's theta_lb exchange
    (all-reduce-max over repository shards, DESIGN.md §5).  Sized to the
    available devices (capped at ``max_shards``) so the same call serves
    the production data axis and the single-device smoke run."""
    n = len(jax.devices())
    if max_shards is not None:
        n = min(n, max_shards)
    return make_mesh((n,), ("data",))
