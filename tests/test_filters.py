"""Filter soundness tests — including the paper's Lemma 6 counterexample."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from scipy.optimize import linear_sum_assignment

from repro.core.filters import compute_iub, kth_largest


def _oracle(w):
    ri, ci = linear_sum_assignment(-w)
    return float(w[ri, ci].sum())


def test_kth_largest():
    x = jnp.asarray([3.0, 1.0, 5.0, 2.0])
    assert float(kth_largest(x, 1)) == 5.0
    assert float(kth_largest(x, 3)) == 2.0
    assert float(kth_largest(x, 10)) == 1.0  # clamps to len


def test_paper_iub_counterexample():
    """The paper's Lemma 6 bound undershoots SO (DESIGN.md §8.5):
    greedy-blocked elements can be re-matched by the optimal matching at
    similarities above s_now."""
    w = np.zeros((3, 3), np.float32)
    w[0, 0] = 1.0
    w[0, 1] = 0.99
    w[1, 0] = 0.99
    w[2, 2] = 0.9
    so = _oracle(w)                      # 2.88
    # stream (desc): (q0,c0,1.0) admitted; 0.99s blocked; (q2,c2,.9) admitted
    S, l, s_now = 1.9, 2, 0.9
    iub_paper = S + min(3 - l, 3 - l) * s_now
    assert iub_paper < so - 1e-6, "expected the unsound bound to undershoot"
    # the corrected per-query-element bound stays valid
    T, d, cap = 1.0 + 0.99 + 0.9, 3, 3
    iub_sound = T + max(0, cap - d) * s_now
    assert iub_sound >= so - 1e-6


def _simulate_stream_bounds(w, alpha):
    """Replay the refinement admission on a dense matrix; yield the sound
    bound after every event and return final (T, d, S)."""
    nq, nc = w.shape
    pairs = [(w[i, j], i, j) for i in range(nq) for j in range(nc)
             if w[i, j] >= alpha]
    pairs.sort(key=lambda p: -p[0])
    qmatched = np.zeros(nq, bool)
    cmatched = np.zeros(nc, bool)
    qseen = np.zeros(nq, bool)
    S = T = 0.0
    d = l = 0
    cap = min(nq, nc)
    bounds = []
    for s, i, j in pairs:
        if not qseen[i]:
            qseen[i] = True
            T += s
            d += 1
        if not qmatched[i] and not cmatched[j]:
            qmatched[i] = cmatched[j] = True
            S += s
            l += 1
        bounds.append(T + max(0, cap - d) * s)
    bounds.append(T)      # stream exhausted: s_now term drops (sub-alpha = 0)
    return bounds, S


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 100_000), st.integers(1, 8), st.integers(1, 8),
       st.sampled_from([0.5, 0.7, 0.8]))
def test_sound_iub_never_undershoots(seed, nq, nc, alpha):
    """Property: iUB'(C) >= SO at every stream position (DESIGN.md §8.5),
    and the greedy partial score S <= SO (Lemma 5)."""
    rng = np.random.default_rng(seed)
    w = rng.random((nq, nc)).astype(np.float32)
    w = np.where(w >= alpha, w, 0.0)
    so = _oracle(w)
    bounds, S = _simulate_stream_bounds(w, alpha)
    assert S <= so + 1e-5
    for b in bounds:
        assert b >= so - 1e-5


def test_compute_iub_modes():
    S = jnp.asarray([1.0, 2.0])
    l = jnp.asarray([1, 2], jnp.int32)
    T = jnp.asarray([1.5, 2.5])
    d = jnp.asarray([2, 3], jnp.int32)
    cap = jnp.asarray([4, 4], jnp.int32)
    seen = jnp.asarray([True, False])
    paper = compute_iub(S, l, T, d, cap, 0.9, seen, "paper")
    sound = compute_iub(S, l, T, d, cap, 0.9, seen, "sound")
    assert abs(float(paper[0]) - (1.0 + 3 * 0.9)) < 1e-6
    assert abs(float(sound[0]) - (1.5 + 2 * 0.9)) < 1e-6
    assert float(paper[1]) > 1e30 and float(sound[1]) > 1e30  # unseen
