"""Exact maximum-weight bipartite matching in JAX (assignment problem).

Shortest-augmenting-path algorithm (Jonker–Volgenant as in Crouse 2016 /
scipy's ``linear_sum_assignment``), expressed with ``lax`` control flow so it
jits, vmaps (batched verification) and runs inside the distributed search
step.  O(n^3).

Semantic-overlap conventions (paper Def. 1):
  * maximization with an *optional* one-to-one matching;
  * weights are in [0, 1] after the alpha-threshold, sub-alpha edges are 0.

We reduce to square min-cost assignment on ``cost = -w`` padded with zeros:
all weights are >= 0, so padded/zero edges are exactly as good as leaving an
element unmatched, and SO == -mincost.  Padded batches (per-element logical
sizes nq/nc <= n) follow the same argument: padding rows/cols carry weight 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_INF = jnp.float32(1e30)


def _solve_square_min(cost: jnp.ndarray, n_aug=None):
    """Min-cost assignment on square ``cost`` (n, n).

    Returns (total_cost, col4row, u, v).  Duals (u, v) satisfy
    u[i] + v[j] <= cost[i, j] with equality on the matching.

    ``n_aug`` (static or traced, <= n) limits augmentation to the first
    ``n_aug`` rows.  When every row beyond ``n_aug`` is all-zero (the
    square padding of a rectangular problem), the restricted solve is
    exact for the *perfect* assignment too: zero rows extend any optimal
    matching of the real rows at zero cost.  Augmenting nq rows instead
    of n cuts the JV cost from O(n^3) to O(nq * n^2) — the common
    verification shape has |Q| << |C|.
    """
    n = cost.shape[0]
    rows = jnp.arange(n)
    if n_aug is None:
        n_aug = n

    def augment(cur_row, carry):
        u, v, row4col, col4row = carry

        # --- Dijkstra scan from cur_row ------------------------------------
        shortest = jnp.full((n,), _INF)
        path = jnp.full((n,), -1, dtype=jnp.int32)   # predecessor row per col
        SR = jnp.zeros((n,), dtype=bool)
        SC = jnp.zeros((n,), dtype=bool)

        def scan_cond(s):
            _, _, _, _, sink, *_ = s
            return sink < 0

        def scan_body(s):
            shortest, path, SR, SC, sink, i, min_val = s
            SR = SR.at[i].set(True)
            d = min_val + cost[i, :] - u[i] - v
            upd = (~SC) & (d < shortest)
            shortest = jnp.where(upd, d, shortest)
            path = jnp.where(upd, i, path)
            masked = jnp.where(SC, _INF, shortest)
            j = jnp.argmin(masked).astype(jnp.int32)
            min_val = masked[j]
            SC = SC.at[j].set(True)
            free = row4col[j] < 0
            sink = jnp.where(free, j, jnp.int32(-1))
            i = jnp.where(free, i, row4col[j])
            return shortest, path, SR, SC, sink, i, min_val

        init = (shortest, path, SR, SC, jnp.int32(-1),
                jnp.int32(cur_row), jnp.float32(0.0))
        shortest, path, SR, SC, sink, _, min_val = jax.lax.while_loop(
            scan_cond, scan_body, init)

        # --- dual update ----------------------------------------------------
        u = u + jnp.where(
            SR,
            jnp.where(rows == cur_row,
                      min_val,
                      min_val - shortest[jnp.clip(col4row, 0, n - 1)]),
            0.0)
        v = v - jnp.where(SC, min_val - shortest, 0.0)

        # --- augment along the alternating path -----------------------------
        def aug_cond(s):
            _, _, _, done = s
            return ~done

        def aug_body(s):
            row4col, col4row, j, _ = s
            i = path[j]
            row4col = row4col.at[j].set(i)
            nxt = col4row[i]
            col4row = col4row.at[i].set(j)
            return row4col, col4row, nxt, i == cur_row

        row4col, col4row, _, _ = jax.lax.while_loop(
            aug_cond, aug_body, (row4col, col4row, sink, jnp.bool_(False)))
        return u, v, row4col, col4row

    u = jnp.zeros((n,), dtype=jnp.float32)
    v = jnp.zeros((n,), dtype=jnp.float32)
    row4col = jnp.full((n,), -1, dtype=jnp.int32)
    col4row = jnp.full((n,), -1, dtype=jnp.int32)
    u, v, row4col, col4row = jax.lax.fori_loop(
        0, n_aug, augment, (u, v, row4col, col4row))
    # rows never augmented (zero padding) stay unmatched at cost 0
    total = jnp.sum(jnp.where(col4row >= 0,
                              cost[rows, jnp.clip(col4row, 0, n - 1)],
                              0.0))
    return total, col4row, u, v


def _pad_to_square_cost(w: jnp.ndarray, nq=None, nc=None):
    """-w padded with zeros; rows/cols beyond logical (nq, nc) get cost 0."""
    n = max(w.shape)
    nq = w.shape[0] if nq is None else nq
    nc = w.shape[1] if nc is None else nc
    cost = jnp.zeros((n, n), dtype=jnp.float32)
    cost = cost.at[: w.shape[0], : w.shape[1]].set(-w.astype(jnp.float32))
    rmask = jnp.arange(n) < nq
    cmask = jnp.arange(n) < nc
    valid = rmask[:, None] & cmask[None, :]
    return jnp.where(valid, cost, 0.0)


@jax.jit
def hungarian_score(w: jnp.ndarray) -> jnp.ndarray:
    """Exact semantic overlap of one weight matrix (nq, nc)."""
    cost = _pad_to_square_cost(w)
    total, _, _, _ = _solve_square_min(cost)
    return -total


@functools.partial(jax.jit, static_argnames=())
def _hungarian_padded(w: jnp.ndarray, nq: jnp.ndarray, nc: jnp.ndarray):
    cost = _pad_to_square_cost(w, nq, nc)
    # only the nq logical rows can carry weight; augmenting just those is
    # exact (see _solve_square_min) and much cheaper when |Q| << |C|
    total, col4row, _, _ = _solve_square_min(cost, n_aug=nq)
    return -total, col4row


# Batched verification: vmap over (B, n, n) padded weights with logical sizes.
hungarian_batch = jax.jit(jax.vmap(_hungarian_padded, in_axes=(0, 0, 0)))
