"""Host<->device dispatch accounting + request-engine counters.

The fused wave program's whole point is eliminating host round-trips
(DESIGN.md §3 / §9 item 6 resolution), so the benchmark needs a number
to show for it.  ``counting()`` installs a process-local counter; every
host->device program dispatch and device->host materialization on the
search path calls :func:`record` with an event tag.  Outside a
``counting()`` block recording is a no-op (one ``is None`` check — the
hot path pays nothing).

Tags follow ``<direction>:<site>``: ``h2d`` = a program dispatch,
``d2h`` = a blocking device-to-host materialization.  The A/B in
``benchmarks/response_time.py --fused`` reports the per-direction sums.

:class:`EngineCounters` is the request engine's per-request / per-wave
instrumentation (DESIGN.md §3.2): true admit->respond latencies (the
number ``serve_batch`` reports per request — NOT one amortized batch
figure), queue-depth samples at every continuous-batching step, and the
stream-cache hit/miss/eviction tallies of the serving window.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import Counter
from typing import Iterator, List, Optional

_ACTIVE: Optional[Counter] = None


def record(event: str, n: int = 1) -> None:
    """Count ``n`` occurrences of ``event`` if a counter is installed."""
    if _ACTIVE is not None:
        _ACTIVE[event] += n


@contextlib.contextmanager
def counting() -> Iterator[Counter]:
    """Install a fresh dispatch counter for the enclosed block (reentrant:
    an inner block shadows, then restores, the outer one)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = Counter()
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def totals(counts: Counter) -> dict:
    """Per-direction sums plus the grand total of a counter's events."""
    h2d = sum(v for k, v in counts.items() if k.startswith("h2d:"))
    d2h = sum(v for k, v in counts.items() if k.startswith("d2h:"))
    return {"h2d_dispatches": h2d, "d2h_transfers": d2h,
            "total": h2d + d2h}


# --------------------------------------------------------- request engine
@dataclasses.dataclass
class RequestTrace:
    """One request's lifecycle timestamps (engine clock seconds)."""

    rid: int
    t_admit: float
    t_stream: float = 0.0          # stream ready (cache hit or built)
    t_first_wave: float = 0.0      # first wave that included the request
    t_respond: float = 0.0
    stream_hit: bool = False
    waves: int = 0                 # waves the request participated in
    deadline: Optional[float] = None
    status: str = "ok"             # 'ok' | 'shed' | 'failed'

    @property
    def latency_s(self) -> float:
        return self.t_respond - self.t_admit

    @property
    def queue_s(self) -> float:
        """Admission-queue wait: admit -> first wave."""
        return self.t_first_wave - self.t_admit

    @property
    def deadline_met(self) -> Optional[bool]:
        if self.deadline is None:
            return None
        return self.t_respond <= self.deadline


def _quantile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(q * len(s)))]


class EngineCounters:
    """Rolling request-engine metrics: per-request traces, queue-depth
    samples (one per continuous-batching step), wave sizes, and the
    stream-cache window deltas."""

    def __init__(self) -> None:
        self.traces: List[RequestTrace] = []
        self.queue_depth: List[int] = []
        self.wave_sizes: List[int] = []
        self.steps = 0
        self.overloaded = 0        # refused: admission queue full
        self.invalid = 0           # refused: failed query validation
        self.resyncs = 0           # epoch resyncs performed

    def observe_step(self, queue_depth: int, wave_size: int) -> None:
        self.steps += 1
        self.queue_depth.append(int(queue_depth))
        self.wave_sizes.append(int(wave_size))

    def observe_respond(self, trace: RequestTrace) -> None:
        self.traces.append(trace)

    def observe_overload(self) -> None:
        self.overloaded += 1

    def observe_invalid(self) -> None:
        self.invalid += 1

    def observe_resync(self) -> None:
        self.resyncs += 1

    def summary(self, cache_stats: Optional[dict] = None) -> dict:
        """Deadline accounting rides along (DESIGN.md §6): latency
        quantiles cover SERVED requests only (a shed request's 'latency'
        is time-to-shed, not service), while the shed tally and the
        deadline-met ratio cover every respond."""
        served = [t for t in self.traces if t.status == "ok"]
        lats = [t.latency_s for t in served]
        queues = [t.queue_s for t in served]
        met = [t.deadline_met for t in self.traces
               if t.deadline_met is not None]
        out = {
            "requests": len(self.traces),
            "served": len(served),
            "shed": sum(t.status == "shed" for t in self.traces),
            "failed": sum(t.status == "failed" for t in self.traces),
            "overloaded": self.overloaded,
            "invalid": self.invalid,
            "resyncs": self.resyncs,
            "steps": self.steps,
            "mean_latency_s": sum(lats) / len(lats) if lats else 0.0,
            "p50_latency_s": _quantile(lats, 0.50),
            "p95_latency_s": _quantile(lats, 0.95),
            "p99_latency_s": _quantile(lats, 0.99),
            "max_latency_s": max(lats) if lats else 0.0,
            "mean_queue_s": sum(queues) / len(queues) if queues else 0.0,
            "mean_queue_depth": (sum(self.queue_depth)
                                 / len(self.queue_depth)
                                 if self.queue_depth else 0.0),
            "max_queue_depth": max(self.queue_depth, default=0),
            "mean_wave_size": (sum(self.wave_sizes) / len(self.wave_sizes)
                               if self.wave_sizes else 0.0),
            "stream_hits": sum(t.stream_hit for t in self.traces),
            "deadlines_met": sum(met),
            "deadlines_missed": len(met) - sum(met),
            "deadline_met_ratio": (sum(met) / len(met)) if met else 1.0,
        }
        if cache_stats is not None:
            out["stream_cache"] = dict(cache_stats)
        return out
