"""The token stream I_e — chunked, blocked-matmul replacement for Faiss+PQ.

Paper §IV: I_e yields (q, t, sim(q, t)) tuples for every vocabulary token t
with sim >= alpha to some query element, in globally descending similarity
order, realised with a Faiss index plus a |Q|-slot priority queue.

TPU adaptation (DESIGN.md §2): the index probe is a blocked similarity
matmul (MXU) over vocabulary tiles — `repro.kernels.cosine_topk` is the
fused Pallas kernel for the serving path; here the same block computation
runs through the jnp provider and the >=alpha entries are compacted host
side (compaction is inherently dynamic-shape, i.e. host work in either
implementation — the paper also walks its priority queue on the host).

The refinement phase consumes the stream *expanded to posting-level events*
through the inverted index (paper: "probing I_s"), still in descending
order:  (set, q, slot, sim) per posting of each streamed token.

Multi-query serving: :func:`build_token_stream_batch` stacks B queries into
one (sum |Q_b| x |V|) blocked sweep — one provider dispatch and one host
compaction per vocab block for the whole batch — and returns per-query
streams bit-identical to B single-query calls.

A stream depends only on (query, provider, alpha) — NOT on the partition —
so the partition scheduler (``repro.core.scheduler``) builds each query's
stream once and expands it through every partition's inverted index,
replacing the historical per-partition rebuild with P calls to
:func:`expand_to_events` per query.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .inverted_index import InvertedIndex
from .types import SetCollection


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """All pairs (q position, token, sim >= alpha), descending by sim."""

    q_pos: np.ndarray    # (T,) int32 — position of the query element in Q
    token: np.ndarray    # (T,) int32 — vocabulary token id
    sim: np.ndarray      # (T,) float32, non-increasing

    def __len__(self) -> int:
        return len(self.sim)


@dataclasses.dataclass(frozen=True)
class EventStream:
    """Posting-level expansion of a TokenStream (still descending by sim)."""

    set_id: np.ndarray   # (E,) int32
    q_pos: np.ndarray    # (E,) int32
    slot: np.ndarray     # (E,) int64 — flat token-array slot (t-side identity)
    sim: np.ndarray      # (E,) float32, non-increasing
    n_tuples: int        # stream tuples that produced these events

    def __len__(self) -> int:
        return len(self.sim)


def _finalize_stream(query: np.ndarray, q_pos: np.ndarray, token: np.ndarray,
                     sim: np.ndarray, vocab: int) -> TokenStream:
    """Identity-pair completion + global descending sort for one query."""
    nq = len(query)
    # Identity pairs (q, q, 1.0) — add any that the provider missed (e.g.
    # degenerate embeddings) and dedupe.
    in_vocab = query < vocab
    id_q = np.arange(nq, dtype=np.int32)[in_vocab]
    id_t = query[in_vocab]
    key = q_pos.astype(np.int64) * vocab + token
    id_key = id_q.astype(np.int64) * vocab + id_t
    missing = ~np.isin(id_key, key)
    q_pos = np.concatenate([q_pos, id_q[missing]])
    token = np.concatenate([token, id_t[missing]])
    sim = np.concatenate([sim, np.ones(missing.sum(), np.float32)])

    # identity pairs must carry sim exactly 1.0 even if the provider returned
    # a slightly different value
    ident = query[q_pos] == token
    sim = np.where(ident, np.float32(1.0), sim)

    order = np.argsort(-sim, kind="stable")
    return TokenStream(q_pos=q_pos[order], token=token[order], sim=sim[order])


def build_token_stream_batch(queries, sim_provider, alpha: float,
                             block_size: int = 4096) -> "list[TokenStream]":
    """Token streams for B queries from ONE blocked similarity sweep.

    The queries are stacked into a single (sum |Q_b|, |V|-block) similarity
    matmul per vocabulary block — B times fewer provider dispatches and one
    host-side ``>= alpha`` compaction per block instead of B of them.  Rows
    of the stacked result are exactly the rows each per-query call would
    compute, and the per-query finalize (identity pairs, stable sort) is
    shared with :func:`build_token_stream`, so the returned streams are
    bit-identical to the per-query path.

    ``sim_provider`` must expose ``query_vs_vocab_block(q_ids, lo, hi)`` and
    ``vocab_size``.  Identity pairs (q, q) are always included with sim 1.0
    (paper §V: a query element is returned for itself on first probe — this
    initialises bounds with the vanilla overlap and covers out-of-vocabulary
    elements).
    """
    queries = [np.asarray(q, dtype=np.int32) for q in queries]
    if not queries:
        return []
    vocab = sim_provider.vocab_size
    stacked = np.concatenate(queries)
    # row ranges of each query inside the stacked matrix
    bounds = np.zeros(len(queries) + 1, np.int64)
    np.cumsum([len(q) for q in queries], out=bounds[1:])

    qs = [[] for _ in queries]
    ts = [[] for _ in queries]
    ss = [[] for _ in queries]
    for lo in range(0, vocab, block_size):
        hi = min(lo + block_size, vocab)
        block = np.asarray(sim_provider.query_vs_vocab_block(stacked, lo, hi))
        qi, tj = np.nonzero(block >= alpha)          # one compaction, B queries
        if not len(qi):
            continue
        vals = block[qi, tj].astype(np.float32)
        # qi is ascending (row-major nonzero), so each query's rows are one
        # contiguous slice; split at the stacked row bounds
        cuts = np.searchsorted(qi, bounds)
        for b in range(len(queries)):
            s, e = cuts[b], cuts[b + 1]
            if e > s:
                qs[b].append((qi[s:e] - bounds[b]).astype(np.int32))
                ts[b].append((tj[s:e] + lo).astype(np.int32))
                ss[b].append(vals[s:e])

    out = []
    for b, query in enumerate(queries):
        if qs[b]:
            q_pos = np.concatenate(qs[b])
            token = np.concatenate(ts[b])
            sim = np.concatenate(ss[b])
        else:
            q_pos = np.zeros(0, np.int32)
            token = np.zeros(0, np.int32)
            sim = np.zeros(0, np.float32)
        out.append(_finalize_stream(query, q_pos, token, sim, vocab))
    return out


def build_token_stream(query: np.ndarray, sim_provider, alpha: float,
                       block_size: int = 4096) -> TokenStream:
    """Single-query token stream (see :func:`build_token_stream_batch`)."""
    return build_token_stream_batch([query], sim_provider, alpha,
                                    block_size)[0]


def expand_to_events(stream: TokenStream, index: InvertedIndex) -> EventStream:
    """Expand stream tuples through the inverted index to per-set events.

    Fully vectorized: posting ranges become one flat gather index built from
    repeated range starts plus within-range offsets (cumulative-offset
    trick) — no Python loop over stream tokens.
    """
    counts = index.posting_counts()
    reps = counts[stream.token]
    total = int(reps.sum())
    q_pos = np.repeat(stream.q_pos, reps)
    sim = np.repeat(stream.sim, reps)
    if total:
        starts = index.tok_indptr[stream.token]      # (T,) posting-range lo
        ends = np.cumsum(reps)                       # event offset per tuple
        within = np.arange(total, dtype=np.int64) - np.repeat(ends - reps,
                                                              reps)
        gather = np.repeat(starts, reps) + within
        set_id = index.posting_set[gather]
        slot = index.posting_slot[gather]
    else:
        set_id = np.zeros(0, dtype=np.int32)
        slot = np.zeros(0, dtype=np.int64)
    return EventStream(set_id=set_id, q_pos=q_pos, slot=slot, sim=sim,
                       n_tuples=len(stream))


def pad_events(events: EventStream, chunk: int):
    """Pad event arrays to a power-of-two number of ``chunk``-sized chunks
    (set_id = -1 padding).  Pow2 chunk counts bound jit recompilations of the
    refinement scan to O(log stream-length) distinct shapes."""
    e = len(events)
    n_chunks = max(1, -(-e // chunk))
    p = 1
    while p < n_chunks:
        p *= 2
    n_chunks = p
    total = n_chunks * chunk
    pad = total - e

    def _pad(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])

    last_sim = events.sim[-1] if e else np.float32(1.0)
    return (
        _pad(events.set_id, -1).reshape(n_chunks, chunk),
        _pad(events.q_pos, 0).reshape(n_chunks, chunk),
        _pad(events.slot, 0).reshape(n_chunks, chunk),
        _pad(events.sim, last_sim).reshape(n_chunks, chunk),
    )
