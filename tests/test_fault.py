"""Fault-tolerant serving plane (DESIGN.md §6): the FleetMonitor health
state machine, the deterministic FaultPlan injector, replica
quarantine + bounded-retry failover behind the AdmissionRouter
(served responses stay bit-identical to the fault-free one-shot path;
no request is lost or duplicated), deadline-aware shedding before any
wave tile is spent, and the drain loop's idle behavior."""
import numpy as np
import pytest

from repro.core import KoiosSearch, SearchParams
from repro.data import sample_queries
from repro.runtime import instrument
from repro.runtime.engine import (AdmissionRouter, RequestEngine,
                                  RouterPolicy)
from repro.runtime.fault import (FaultConfig, FaultEvent, FaultPlan,
                                 FleetMonitor, ReplicaCrash,
                                 TransientVerifierError)


def _params():
    return SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8)


def _fake_clock(tick=0.0):
    """Virtual clock: (now, advance, sleep, sleep_log).  ``tick`` makes
    every read advance a hair so step latencies are nonzero (the
    straggler detector filters zero-latency heartbeats)."""
    t = [1000.0]
    log = []

    def now():
        t[0] += tick
        return t[0]

    def advance(dt):
        t[0] += dt

    def sleep(dt):
        log.append(dt)
        t[0] += dt

    return now, advance, sleep, log


# ------------------------------------------------- FleetMonitor machine
def test_fleet_monitor_heartbeat_timeout_and_restore():
    """Heartbeat timeout -> failed; evict -> unhealthy; restore ->
    healthy with a fresh heartbeat (no instant re-eviction)."""
    clock, advance, _, _ = _fake_clock()
    mon = FleetMonitor(3, FaultConfig(heartbeat_timeout=1.0), clock=clock)
    for h in range(3):
        mon.heartbeat(h, step=1, step_latency=0.1)
    advance(0.5)
    assert mon.failed_hosts() == []
    mon.heartbeat(0, step=2, step_latency=0.1)
    mon.heartbeat(2, step=2, step_latency=0.1)
    advance(0.8)                       # host 1 last beat 1.3s ago
    assert mon.failed_hosts() == [1]
    mon.evict([1])
    assert mon.healthy_count() == 2
    assert mon.failed_hosts() == []    # unhealthy hosts are not re-flagged
    mon.restore(1)
    assert mon.healthy_count() == 3
    assert mon.failed_hosts() == []    # restore refreshed the heartbeat


def test_fleet_monitor_straggler_patience():
    """A straggler is evicted only after ``patience`` consecutive slow
    steps, and one fast step resets the count."""
    clock, _, _, _ = _fake_clock()
    mon = FleetMonitor(3, FaultConfig(straggler_factor=2.0,
                                      straggler_patience=2), clock=clock)
    for h in range(3):
        mon.heartbeat(h, 1, 0.1)
    mon.heartbeat(2, 1, 1.0)           # 10x the median
    assert mon.stragglers() == []      # patience 1 of 2
    mon.heartbeat(2, 2, 0.1)           # recovered
    assert mon.stragglers() == []      # count reset
    mon.heartbeat(2, 3, 1.0)
    assert mon.stragglers() == []
    mon.heartbeat(2, 4, 1.0)
    assert mon.stragglers() == [2]     # two consecutive slow steps
    mon.evict([2])
    assert mon.healthy_count() == 2


# ------------------------------------------------------ FaultPlan data
def test_fault_plan_seeded_and_single_fire():
    a = FaultPlan.random(seed=3, replicas=4, steps=10)
    b = FaultPlan.random(seed=3, replicas=4, steps=10)
    c = FaultPlan.random(seed=4, replicas=4, steps=10)
    assert a.describe() == b.describe()       # same seed, same schedule
    assert a.describe() != c.describe()
    assert all(e["kind"] in ("crash", "stall", "verify_error")
               for e in a.describe())

    ev = FaultEvent("crash", replica=1, step=2)
    plan = FaultPlan([ev, FaultEvent("stall", 0, 2, stall_s=0.5)])
    assert plan.pending() == 2
    assert plan.take(1, 2) == [ev]
    assert plan.take(1, 2) == []              # fires exactly once
    assert plan.pending() == 1
    assert plan.fired == [ev]
    with pytest.raises(AssertionError):
        FaultEvent("meteor", 0, 1)


# -------------------------------------------------- router failover
def test_router_crash_failover_bitwise(small_world):
    """The tentpole guarantee: kill 1 of 4 replicas mid-trace and the
    router still completes the trace — every served response
    bit-identical to the fault-free one-shot path, retried requests
    appear exactly once (no loss, no duplication), and global rid
    order is preserved."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 8, seed=51)
    ref = KoiosSearch(coll, sim, params, partitions=2).search_batch(queries)

    clock, advance, sleep, _ = _fake_clock()
    plan = FaultPlan([FaultEvent("crash", replica=1, step=2)])
    router = AdmissionRouter(coll, sim, params, replicas=4, partitions=2,
                            fault_plan=plan, clock=clock, sleep=sleep)
    resp = router.serve(queries)

    assert [r.rid for r in resp] == list(range(len(queries)))  # no loss/dup
    assert plan.pending() == 0                  # the crash really fired
    retried = [r for r in resp if r.status == "retried"]
    assert retried and all(r.retries == 1 for r in retried)
    assert all(r.status in ("ok", "retried") for r in resp)
    for r in resp:                              # served == fault-free
        a = ref[r.rid]
        assert np.array_equal(r.result.ids, a.ids)
        assert np.array_equal(r.result.lb, a.lb)

    s = router.summary()
    assert s["quarantines"] == 1 and s["healthy_replicas"] == 3
    assert s["retries"] == len(retried) and s["failed"] == 0
    assert s["requests"] == len(queries)  # traces across the fleet


def test_router_all_quarantined_fails_cleanly(small_world):
    """Satellite: with every replica quarantined the router responds
    ``status='failed'`` with a reason — never an unhandled KeyError —
    both for in-flight requests (after the retry budget) and for fresh
    admissions."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 4, seed=52)

    clock, advance, sleep, _ = _fake_clock()
    plan = FaultPlan([FaultEvent("crash", 0, 1), FaultEvent("crash", 1, 1)])
    router = AdmissionRouter(coll, sim, params, replicas=2, partitions=2,
                            policy=RouterPolicy(retry_budget=1),
                            fault_plan=plan, clock=clock, sleep=sleep)
    resp = router.serve(queries)
    assert [r.rid for r in resp] == list(range(len(queries)))
    assert all(r.status == "failed" and r.reason for r in resp)
    assert all(len(r.result.ids) == 0 for r in resp)
    assert router.summary()["healthy_replicas"] == 0

    gid = router.submit(queries[0])             # admission after the fact
    late = router.drain()
    assert [r.rid for r in late] == [gid]
    assert late[0].status == "failed"
    assert "quarantined" in late[0].reason


def test_router_transient_error_quarantines_then_revives(small_world):
    """A transient verifier error quarantines the replica (its requests
    fail over, served bit-identically); after the cooldown the replica
    is revived and serves again."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 4, seed=53)
    ref = KoiosSearch(coll, sim, params, partitions=2).search_batch(queries)

    clock, advance, sleep, _ = _fake_clock()
    plan = FaultPlan([FaultEvent("verify_error", 0, 1)])
    router = AdmissionRouter(coll, sim, params, replicas=2, partitions=2,
                            policy=RouterPolicy(revive_after_s=0.1),
                            fault_plan=plan, clock=clock, sleep=sleep)
    resp = router.serve(queries)
    assert [r.rid for r in resp] == list(range(len(queries)))
    assert all(r.status in ("ok", "retried") for r in resp)
    assert any(r.status == "retried" for r in resp)
    for r in resp:
        assert np.array_equal(r.result.ids, ref[r.rid].ids)
        assert np.array_equal(r.result.lb, ref[r.rid].lb)

    advance(0.2)                                # past the cooldown
    router.step()                               # revive check runs
    assert router.summary()["healthy_replicas"] == 2
    again = router.serve(queries)               # the revived replica works
    assert all(r.status == "ok" for r in again)
    for r, a in zip(again, ref):                # gids keep counting up —
        assert np.array_equal(r.result.ids, a.ids)   # compare by position
    assert sum(1 for q in router.quarantine_log
               if q["reason"] == "revived") == 1


def test_router_hung_step_quarantined(small_world):
    """A stall longer than the heartbeat timeout is a hang: the replica
    is quarantined right after the step returns, its requests fail over,
    and the trace still completes bit-identically."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 4, seed=54)
    ref = KoiosSearch(coll, sim, params, partitions=2).search_batch(queries)

    clock, advance, sleep, _ = _fake_clock(tick=1e-6)
    plan = FaultPlan([FaultEvent("stall", 0, 1, stall_s=2.0)])
    router = AdmissionRouter(coll, sim, params, replicas=2, partitions=2,
                            fault_config=FaultConfig(heartbeat_timeout=0.5),
                            fault_plan=plan, clock=clock, sleep=sleep)
    resp = router.serve(queries)
    assert [r.rid for r in resp] == list(range(len(queries)))
    assert all(r.status in ("ok", "retried") for r in resp)
    for r in resp:
        assert np.array_equal(r.result.ids, ref[r.rid].ids)
        assert np.array_equal(r.result.lb, ref[r.rid].lb)
    hung = [q for q in router.quarantine_log if "hung" in q["reason"]]
    assert len(hung) == 1 and hung[0]["replica"] == 0


def test_router_straggler_stalls_quarantined(small_world):
    """Repeated sub-timeout stalls trip the straggler detector after
    ``straggler_patience`` steps; the fleet keeps serving."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 6, seed=55)

    clock, advance, sleep, _ = _fake_clock(tick=1e-6)
    plan = FaultPlan([FaultEvent("stall", 0, s, stall_s=0.05)
                      for s in (1, 2, 3)])
    router = AdmissionRouter(
        coll, sim, params, replicas=3, partitions=2,
        fault_config=FaultConfig(heartbeat_timeout=60.0,
                                 straggler_factor=3.0,
                                 straggler_patience=2),
        fault_plan=plan, clock=clock, sleep=sleep)
    resp = router.serve(queries)
    assert [r.rid for r in resp] == list(range(len(queries)))
    assert all(r.status in ("ok", "retried") for r in resp)
    strag = [q for q in router.quarantine_log
             if "straggler" in q["reason"]]
    assert len(strag) == 1 and strag[0]["replica"] == 0


# ----------------------------------------------------- deadline shedding
def test_engine_sheds_doomed_requests_before_any_wave(small_world):
    """Acceptance: under tight deadlines the doomed requests respond
    ``status='shed'`` BEFORE wave dispatch — the instrument event count
    matches, their traces show zero waves, and the engine's wave sizes
    account only the served requests' tiles."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 4, seed=56)
    ref = KoiosSearch(coll, sim, params, partitions=2).search_batch(queries)

    clock, advance, sleep, _ = _fake_clock()
    eng = RequestEngine(coll, sim, params, partitions=2,
                        shed_deadlines=True, clock=clock, sleep=sleep)
    now = clock()
    deadlines = [None, now - 0.001, None, now - 0.5]   # 1 and 3 are doomed
    with instrument.counting() as c:
        resp = eng.serve(queries, deadlines=deadlines)

    assert [r.rid for r in resp] == [0, 1, 2, 3]
    shed = [r for r in resp if r.status == "shed"]
    assert [r.rid for r in shed] == [1, 3]
    assert c["engine:shed"] == 2
    for r in shed:
        assert r.waves == 0                      # no wave tile spent
        assert len(r.result.ids) == 0
        assert r.deadline_met is False
        assert "deadline unreachable" in r.reason
    for r in resp:
        if r.status == "ok":
            assert np.array_equal(r.result.ids, ref[r.rid].ids)
            assert np.array_equal(r.result.lb, ref[r.rid].lb)
    # wave accounting: only the 2 served requests' tiles ever ran
    assert sum(eng.counters.wave_sizes) == 2 * len(eng.partitions)
    s = eng.summary()
    assert s["shed"] == 2 and s["served"] == 2 and s["requests"] == 4
    assert 0.0 <= s["deadline_met_ratio"] <= 1.0
    assert s["p99_latency_s"] >= s["p50_latency_s"] >= 0.0


def test_engine_sheds_inflight_when_estimate_says_doomed(small_world):
    """Mid-flight shedding: once the smoothed wave time says the
    remaining partitions cannot meet the deadline, the request is
    dropped from the NEXT wave (its spent waves are reported) and the
    rest of the cohort is unaffected."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 2, seed=57)
    ref = KoiosSearch(coll, sim, params, partitions=4).search_batch(queries)

    clock, advance, sleep, _ = _fake_clock()
    eng = RequestEngine(coll, sim, params, partitions=4,
                        shed_deadlines=True, clock=clock, sleep=sleep)
    eng.submit(queries[0])
    eng.submit(queries[1], deadline=clock() + 10.0)
    eng.step()                                   # wave 1 of 4 runs for both
    eng._wave_ewma = 100.0          # waves are 'measured' slow: 3 waves
    resp = []                       # to go x 100s each >> 10s of headroom
    while eng.pending():
        advance(0.01)
        resp.extend(eng.step())
    resp.sort(key=lambda r: r.rid)

    assert [r.status for r in resp] == ["ok", "shed"]
    assert resp[1].waves == 1                    # one wave was spent...
    assert "deadline unreachable" in resp[1].reason
    assert np.array_equal(resp[0].result.ids, ref[0].ids)  # ...cohort fine
    assert np.array_equal(resp[0].result.lb, ref[0].lb)


# ------------------------------------------------------- drain behavior
def test_drain_sleeps_full_arrival_gap_no_busy_spin(small_world):
    """Satellite: a known future arrival is slept through in ONE sleep
    call (the historical path woke every ``max_idle_wait_s`` to
    re-discover the same gap ~100x/s)."""
    coll, sim = small_world
    clock, advance, sleep, log = _fake_clock()
    eng = RequestEngine(coll, sim, _params(), partitions=1,
                        clock=clock, sleep=sleep)
    q = sample_queries(coll, 1, seed=58)
    eng.submit(q[0], arrival=clock() + 1.0)
    resp = eng.drain(max_idle_wait_s=0.01)
    assert len(resp) == 1 and resp[0].status == "ok"
    arrival_sleeps = [dt for dt in log if dt > 0.01]
    assert len(arrival_sleeps) == 1              # one sleep covers the gap
    assert arrival_sleeps[0] == pytest.approx(1.0)
    assert len(log) <= 2                         # no 100-iteration spin


def test_evacuate_hands_back_requests_and_keeps_resources(small_world):
    """Evacuation empties the lifecycle (no duplicate responds possible)
    but keeps request-independent resources — the revived replica
    serves fresh traffic bit-identically, streams still cached."""
    coll, sim = small_world
    params = _params()
    queries = sample_queries(coll, 3, seed=59)
    ref = KoiosSearch(coll, sim, params, partitions=2).search_batch(queries)

    clock, advance, sleep, _ = _fake_clock()
    eng = RequestEngine(coll, sim, params, partitions=2,
                        clock=clock, sleep=sleep)
    rids = [eng.submit(q) for q in queries]
    eng.step()                                   # mid-flight
    done, specs = eng.evacuate()
    assert done == []
    assert [s[0] for s in specs] == rids         # every request handed back
    assert eng.pending() == 0                    # nothing left to respond
    assert len(eng.stream_cache) >= 1            # cache survives

    resp = eng.serve(queries)                    # revived replica serves
    for r, a in zip(resp, ref):
        assert r.status == "ok"
        assert np.array_equal(r.result.ids, a.ids)
        assert np.array_equal(r.result.lb, a.lb)
    assert all(r.stream_hit for r in resp)       # ...from the kept cache


def test_engine_crash_and_verify_faults_raise(small_world):
    """Standalone engines surface injected faults as the typed
    exceptions the router consumes."""
    coll, sim = small_world
    q = sample_queries(coll, 1, seed=60)
    clock, advance, sleep, _ = _fake_clock()
    eng = RequestEngine(coll, sim, _params(), partitions=1,
                        fault_plan=FaultPlan([FaultEvent("crash", 0, 1)]),
                        clock=clock, sleep=sleep)
    eng.submit(q[0])
    with pytest.raises(ReplicaCrash):
        eng.step()

    eng2 = RequestEngine(
        coll, sim, _params(), partitions=1,
        fault_plan=FaultPlan([FaultEvent("verify_error", 0, 1)]),
        clock=clock, sleep=sleep)
    eng2.submit(q[0])
    with pytest.raises(TransientVerifierError):
        eng2.step()
