"""Partition scheduler: overlapped execution is bit-identical to the
sequential partition loop, the fused on-device wave schedule is
bit-identical to both (across partitions x batch x verifier modes), theta_lb
is monotone over scheduler steps, and the mesh bound exchange changes
nothing."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (EmbeddingSimilarity, ExecutionPlan, KoiosSearch,
                        SearchParams, partition_ranges, run_plan)
from repro.data import make_collection, make_embeddings, sample_queries


@pytest.mark.parametrize("verifier", ["hungarian", "auction", "hybrid"])
@pytest.mark.parametrize("partitions", [1, 2, 4])
@pytest.mark.parametrize("batch", [1, 8])
def test_overlap_matches_sequential_bitwise(small_world, verifier,
                                            partitions, batch):
    """The tentpole guarantee: the overlapped partition schedule (async
    refinement dispatch, global verify queue, bidirectional bounds)
    returns the same ids and the same lb/ub floats as the pre-scheduler
    sequential running-max loop."""
    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          verifier=verifier)
    engine = KoiosSearch(coll, sim, params, partitions=partitions)
    queries = sample_queries(coll, batch, seed=5)
    seq = engine.search_batch(queries, schedule="sequential")
    ovl = engine.search_batch(queries, schedule="overlap")
    for a, b in zip(seq, ovl):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.lb, b.lb)          # bit-identical floats
        assert np.array_equal(a.ub, b.ub)


@pytest.mark.parametrize("verifier", ["hungarian", "auction", "hybrid"])
@pytest.mark.parametrize("partitions", [1, 2, 4])
@pytest.mark.parametrize("batch", [1, 8])
def test_fused_matches_overlap_and_sequential_bitwise(small_world, verifier,
                                                      partitions, batch):
    """The PR-3 tentpole guarantee: the fused on-device wave schedule
    (refinement chunk scans + compaction + the first R verification
    rounds as ONE device program per partition wave, interpret mode on
    CPU) returns the same ids and the same lb/ub floats as both host
    schedules."""
    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          verifier=verifier, fused="interpret")
    engine = KoiosSearch(coll, sim, params, partitions=partitions)
    queries = sample_queries(coll, batch, seed=5)
    seq = engine.search_batch(queries, schedule="sequential")
    ovl = engine.search_batch(queries, schedule="overlap")
    fus = engine.search_batch(queries, schedule="fused")
    st = engine.scheduler_stats
    assert st.schedule == "fused"          # really took the wave path
    assert st.waves == partitions
    for a, b, c in zip(seq, ovl, fus):
        assert np.array_equal(a.ids, c.ids)
        assert np.array_equal(a.lb, c.lb)          # bit-identical floats
        assert np.array_equal(a.ub, c.ub)
        assert np.array_equal(b.ids, c.ids)
        assert np.array_equal(b.lb, c.lb)
        assert np.array_equal(b.ub, c.ub)


def test_fused_falls_back_to_overlap_off_tpu(small_world):
    """Without the interpret opt-in the fused schedule must resolve to
    overlap on a CPU backend (and still return exact results)."""
    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8)
    engine = KoiosSearch(coll, sim, params, partitions=2)   # schedule=fused
    q = sample_queries(coll, 1, seed=9)[0]
    r_fused = engine.search(q)
    assert engine.scheduler_stats.schedule == "overlap"
    assert engine.scheduler_stats.waves == 0
    r_seq = engine.search(q, schedule="sequential")
    assert np.array_equal(r_fused.ids, r_seq.ids)
    assert np.array_equal(r_fused.lb, r_seq.lb)


def test_fused_with_mesh_exchange_identical(small_world):
    """The fused schedule with the on-device all-reduce-max bound exchange
    (single-device mesh: identity) changes no result."""
    from repro.launch.mesh import bound_exchange_mesh
    from repro.runtime.sharding import bound_exchange_for

    coll, sim = small_world
    mesh = bound_exchange_mesh()
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8,
                          fused="interpret")
    host = KoiosSearch(coll, sim, params, partitions=4)
    meshed = KoiosSearch(coll, sim, params, partitions=4, mesh=mesh,
                         bound_exchange=bound_exchange_for(mesh))
    queries = sample_queries(coll, 3, seed=41)
    for a, b in zip(host.search_batch(queries, schedule="fused"),
                    meshed.search_batch(queries, schedule="fused")):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.lb, b.lb)


@pytest.mark.parametrize("partitions", [2, 4])
def test_token_balanced_partitioning(small_world, partitions):
    """Size-balanced (token-count) partitioning (DESIGN.md §9 item 5,
    resolved): identical top-k to the linspace set-range split, and every
    partition's token count within 10% of the ideal share."""
    coll, sim = small_world
    sizes = coll.set_sizes
    bounds = partition_ranges(sizes, partitions, by="tokens")
    assert bounds[0] == 0 and bounds[-1] == coll.num_sets
    assert np.all(np.diff(bounds) > 0)             # non-empty partitions
    tokens = np.array([sizes[lo:hi].sum()
                       for lo, hi in zip(bounds[:-1], bounds[1:])])
    ideal = coll.total_tokens / partitions
    assert tokens.max() <= 1.1 * ideal, (tokens, ideal)

    # token-skewed repository: one huge set drags every greedy cut right;
    # the forward+backward passes must still yield non-empty partitions
    skewed = partition_ranges(np.array([1, 1, 1, 100]), 4, by="tokens")
    assert np.array_equal(skewed, [0, 1, 2, 3, 4])

    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8)
    by_sets = KoiosSearch(coll, sim, params, partitions=partitions)
    by_tokens = KoiosSearch(coll, sim, params, partitions=partitions,
                            partition_by="tokens")
    queries = sample_queries(coll, 4, seed=13)
    for a, b in zip(by_sets.search_batch(queries),
                    by_tokens.search_batch(queries)):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.lb, b.lb)


def test_search_is_search_batch_is_the_scheduler(small_world):
    """Entry-point collapse: ``search`` == ``search_batch`` with B=1 ==
    a 1-partition plan through ``run_plan`` (plus the top-k merge)."""
    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8)
    engine = KoiosSearch(coll, sim, params)
    q = sample_queries(coll, 1, seed=23)[0]
    r_single = engine.search(q)
    (r_batch,) = engine.search_batch([q])
    assert np.array_equal(r_single.ids, r_batch.ids)
    assert np.array_equal(r_single.lb, r_batch.lb)
    assert r_single.stats.as_dict() == r_batch.stats.as_dict()
    plan = ExecutionPlan(engine.partitions, [q], pool_coll=coll)
    [tiles] = run_plan(plan, sim, params)
    from repro.core import merge_topk
    r_plan = merge_topk(tiles, params.k)
    assert np.array_equal(r_single.ids, r_plan.ids)
    assert np.array_equal(r_single.lb, r_plan.lb)


def test_batch_rows_independent_of_batch_composition(small_world):
    """A query's trajectory through the overlapped scheduler must not
    depend on which other queries share the plan (per-query bounds, shared
    execution only)."""
    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8)
    engine = KoiosSearch(coll, sim, params, partitions=3)
    queries = sample_queries(coll, 4, seed=31)
    batch = engine.search_batch(queries)
    for q, rb in zip(queries, batch):
        rs = engine.search(q)
        assert np.array_equal(rs.ids, rb.ids)
        assert np.array_equal(rs.lb, rb.lb)
        assert rs.stats.as_dict() == rb.stats.as_dict()


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 4))
def test_theta_monotone_over_scheduler_steps(seed, partitions):
    """Property: every query's theta_lb is non-decreasing across the
    scheduler's exchange points (initial refinement exchange + one per
    verification round), and the final bound is what the tiles report."""
    rng = np.random.default_rng(seed)
    coll = make_collection(num_sets=60, vocab_size=300, avg_size=6,
                           max_size=12, seed=seed)
    emb = make_embeddings(300, dim=16, cluster_size=3.0, seed=seed)
    sim = EmbeddingSimilarity(emb)
    params = SearchParams(k=3, alpha=0.8, chunk_size=64, verify_batch=4)
    engine = KoiosSearch(coll, sim, params, partitions=partitions)
    queries = sample_queries(coll, 3, seed=seed)
    results = engine.search_batch(queries)
    trace = engine.scheduler_stats.theta_trace
    assert len(trace) >= 1
    for prev, cur in zip(trace, trace[1:]):
        assert np.all(cur >= prev - 1e-12), (prev, cur)
    for qi, res in enumerate(results):
        # the traced bound is a certified lower bound on the k-th score
        if len(res.lb) >= params.k:
            assert trace[-1][qi] <= res.lb[params.k - 1] + 1e-6


def test_mesh_bound_exchange_identical(small_world):
    """Plugging the mesh all-reduce-max into the exchange changes no
    result (single-device mesh: the reduction is the identity)."""
    from repro.launch.mesh import bound_exchange_mesh
    from repro.runtime.sharding import all_reduce_max, bound_exchange_for

    mesh = bound_exchange_mesh()
    v = np.array([0.25, 1.5, 0.0], np.float32)
    np.testing.assert_array_equal(all_reduce_max(v, mesh), v)

    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8)
    host = KoiosSearch(coll, sim, params, partitions=4)
    meshed = KoiosSearch(coll, sim, params, partitions=4,
                         bound_exchange=bound_exchange_for(mesh))
    queries = sample_queries(coll, 3, seed=41)
    for a, b in zip(host.search_batch(queries), meshed.search_batch(queries)):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.lb, b.lb)


def test_scheduler_stats_populated(small_world):
    coll, sim = small_world
    params = SearchParams(k=5, alpha=0.8, chunk_size=64, verify_batch=8)
    engine = KoiosSearch(coll, sim, params, partitions=4)
    queries = sample_queries(coll, 2, seed=7)
    engine.search_batch(queries)
    st = engine.scheduler_stats
    assert st.tiles == 4 * len(queries)
    assert st.rounds >= 1
    assert st.fused_requests >= st.rounds
    assert st.backward_raises <= st.bound_raises
    d = st.as_dict()
    assert isinstance(d["theta_trace"], list)
