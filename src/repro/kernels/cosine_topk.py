"""Pallas TPU kernel: blocked cosine similarity + running top-k.

This is the token-stream generator (paper §IV): it replaces the Faiss index
probe with an MXU matmul over vocabulary tiles and an on-chip running top-k
merge, so the (|Q| x |V|) score matrix never round-trips to HBM.

Grid: one step per vocabulary tile of ``bv`` rows.  The query block and the
running top-k output blocks have constant index maps, so they stay resident
in VMEM across the sequential grid sweep (revisiting semantics); each step
computes a (nq, bv) score tile and folds it into the running (nq, k) top-k
with k max+mask selection passes.

VMEM working set per step:  nq*d (queries) + bv*d (tile) + nq*bv (scores)
+ 2*nq*k (running top-k).  With nq=256, d=256, bv=512, k=32 (f32):
256KB + 512KB + 512KB + 64KB ~= 1.3 MB — comfortably inside the ~16 MB VMEM
budget, and the matmul contraction dim d and tile dim bv are multiples of
the 128-lane MXU tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30  # python scalar: jnp constants may not be closure-captured by kernels


def _kernel(qe_ref, ev_ref, vals_ref, idx_ref, *, k: int, bv: int,
            nv_real: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, _NEG)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    qe = qe_ref[...]                       # (nq, d)
    ev = ev_ref[...]                       # (bv, d)
    scores = jax.lax.dot_general(
        qe, ev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (nq, bv)
    base = step * bv
    cand_idx = base + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(cand_idx < nv_real, scores, _NEG)

    comb_v = jnp.concatenate([vals_ref[...], scores], axis=1)
    comb_i = jnp.concatenate([idx_ref[...], cand_idx], axis=1)
    nq = comb_v.shape[0]
    out_v = jnp.zeros((nq, k), jnp.float32)
    out_i = jnp.zeros((nq, k), jnp.int32)

    def select(j, st):
        cv, ci, ov, oi = st
        m = jnp.max(cv, axis=1)
        a = jnp.argmax(cv, axis=1)
        picked = jnp.take_along_axis(ci, a[:, None], axis=1)
        ov = jax.lax.dynamic_update_slice(ov, m[:, None], (0, j))
        oi = jax.lax.dynamic_update_slice(oi, picked, (0, j))
        cv = cv.at[jnp.arange(nq), a].set(_NEG)
        return cv, ci, ov, oi

    _, _, out_v, out_i = jax.lax.fori_loop(
        0, k, select, (comb_v, comb_i, out_v, out_i))
    vals_ref[...] = out_v
    idx_ref[...] = out_i


@functools.partial(jax.jit,
                   static_argnames=("k", "bv", "interpret"))
def cosine_topk(qe: jnp.ndarray, ev: jnp.ndarray, k: int, bv: int = 512,
                interpret: bool = False):
    """Top-k cosine scores of each query row against all vocab rows.

    qe: (nq, d) and ev: (nv, d), both L2-normalized.  Returns
    (vals (nq, k), idx (nq, k)), descending per row.
    """
    nq, d = qe.shape
    nv, _ = ev.shape
    # pad vocab to a multiple of bv
    nv_pad = -(-nv // bv) * bv
    if nv_pad != nv:
        ev = jnp.pad(ev, ((0, nv_pad - nv), (0, 0)))
    grid = (nv_pad // bv,)
    kernel = functools.partial(_kernel, k=k, bv=bv, nv_real=nv)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nq, d), lambda i: (0, 0)),
            pl.BlockSpec((bv, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nq, k), lambda i: (0, 0)),
            pl.BlockSpec((nq, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        interpret=interpret,
    )(qe.astype(jnp.float32), ev.astype(jnp.float32))
    return vals, idx
