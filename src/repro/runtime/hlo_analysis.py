"""HLO-text analysis: collective census + roofline terms.

``cost_analysis()`` gives HLO FLOPs/bytes but (a) does not multiply
while-loop trip counts (XLA:CPU, verified by calibration in
launch/dryrun.py) and (b) has no collective-bytes entry.  This module:

  * parses the compiled SPMD module text and sums, per collective kind,
    the *operand* bytes of every all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute (per-device shard sizes — the SPMD
    module is the per-device program);
  * converts to roofline terms with the v5e constants.

Roofline model (per device, per step):
  compute    = flops / PEAK_FLOPS
  memory     = bytes_accessed / HBM_BW
  collective = sum_k operand_bytes_k * ring_factor_k / ICI_BW
where ring_factor models bytes-through-a-link per ring collective:
all-gather & reduce-scatter & all-to-all ~ (n-1)/n ~= 1, all-reduce ~ 2,
collective-permute = 1.  (n is unknowable cheaply per-op from text; the
(n-1)/n ~= 1 approximation is conservative within 7% for n >= 16.)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

import numpy as np

# TPU v5e constants (per chip) — the assignment's hardware model.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DEF_RE = re.compile(r"^\s*(%[\w.\-]+|[\w.\-]+) = (.+?) ([\w\-]+)\((.*)\)",
                     re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_RING_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def normalize_cost_analysis(ca) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returns a flat ``{counter: value}`` dict, newer versions a
    list with one such dict per program executable, and some backends
    return ``None``.  Callers always want the entry-program dict; indexing
    ``["flops"]`` / ``.get`` on the raw return crashes on the list shape.
    """
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    if isinstance(ca, (list, tuple)):
        for entry in ca:
            if entry:
                return dict(entry)
        return {}
    raise TypeError(f"unrecognized cost_analysis() return: {type(ca)!r}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    operand_bytes: Dict[str, float]
    result_bytes: Dict[str, float]

    @property
    def total_operand_bytes(self) -> float:
        return float(sum(self.operand_bytes.values()))

    def link_bytes(self) -> float:
        """Ring-model bytes through a device's link."""
        return float(sum(self.operand_bytes[k] * _RING_FACTOR[k]
                         for k in self.operand_bytes))

    def as_dict(self) -> dict:
        return {"counts": dict(self.counts),
                "operand_bytes": dict(self.operand_bytes),
                "result_bytes": dict(self.result_bytes),
                "link_bytes": self.link_bytes()}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Census of collective ops with operand/result byte sums.

    Operand sizes come from a def-table of every named instruction; ops
    inside while bodies appear once (caller multiplies by trip counts via
    the probe-extrapolation, launch/dryrun.py)."""
    defs: Dict[str, int] = {}
    counts = {k: 0 for k in _COLLECTIVES}
    op_bytes = {k: 0.0 for k in _COLLECTIVES}
    res_bytes = {k: 0.0 for k in _COLLECTIVES}

    for m in _DEF_RE.finditer(hlo_text):
        name, type_str, op, args = m.groups()
        defs[name.lstrip("%")] = _type_bytes(type_str)
        kind = None
        base = op.rstrip("-start").rstrip("-done")
        for k in _COLLECTIVES:
            if op == k or op == k + "-start":
                kind = k
                break
        if kind is None:
            continue
        counts[kind] += 1
        res_bytes[kind] += _type_bytes(type_str)
        # operand bytes: resolve argument names against the def table
        total = 0
        for arg in args.split(","):
            arg = arg.strip().split(" ")[-1].lstrip("%")
            if arg in defs:
                total += defs[arg]
        if total == 0:
            # operands not yet defined inline (e.g. parameters) — fall back
            # to result size (exact for all-reduce/permute)
            total = _type_bytes(type_str)
        op_bytes[kind] += total
    return CollectiveStats(counts=counts, operand_bytes=op_bytes,
                           result_bytes=res_bytes)


def roofline_terms(flops: float, bytes_accessed: float,
                   link_bytes: float) -> dict:
    """Per-device roofline terms in seconds + the dominant bottleneck."""
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = link_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["bottleneck"] = dom.replace("_s", "")
    terms["step_lower_bound_s"] = bound
    terms["roofline_fraction"] = (t_compute / bound) if bound > 0 else 0.0
    return terms
